"""Unified decoder LM covering the four assigned families.

One functional model class drives every assigned architecture:

* ``dense``  — pre-norm GQA/MQA attention + gated MLP
* ``moe``    — same attention, MoE FFN (optional dense prelude layers)
* ``ssm``    — Mamba-2 SSD mixer blocks, attention-free
* ``hybrid`` — Mamba-2 backbone + one *shared* attention tile applied every
               ``shared_attn_every`` blocks (Zamba-2).  The shared tile is the
               dual of a Vespa multi-replica tile: one physical instance,
               many logical users.

Layers are stacked (leading L dim) and driven by ``lax.scan`` so the HLO and
compile time stay O(1) in depth; ``jax.checkpoint`` on the scan body gives
activation rematerialization for the train step.

Three entry points mirror the assigned input shapes:
``forward`` (train), ``prefill`` (→ cache), ``decode_step`` (cache → cache).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MoE
from repro.models.layers import AttnOptions, DATA, MODEL, MODEL_FULL
from repro.models.params import (ParamSpec, abstract_params, init_params,
                                 is_spec, shard_activation, spec)


def _stack_specs(tree, n: int):
    """Add a leading stacked-layers dim to every ParamSpec leaf."""
    def one(s: ParamSpec):
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                         s.init, s.scale)
    return jax.tree_util.tree_map(one, tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Block definitions (single layer, unstacked)
# ---------------------------------------------------------------------------


def _attn_spec(cfg: ArchConfig):
    return L.mla_spec(cfg) if cfg.attn_type == "mla" else L.gqa_spec(cfg)


def _dense_block_spec(cfg: ArchConfig, d_ff: Optional[int] = None):
    return {
        "attn_norm": L.rms_norm_spec(cfg.d_model),
        "attn": _attn_spec(cfg),
        "mlp_norm": L.rms_norm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg.d_model, d_ff or cfg.d_ff),
    }


def _moe_block_spec(cfg: ArchConfig):
    return {
        "attn_norm": L.rms_norm_spec(cfg.d_model),
        "attn": _attn_spec(cfg),
        "mlp_norm": L.rms_norm_spec(cfg.d_model),
        "moe": MoE.moe_spec(cfg),
    }


def _ssm_block_spec(cfg: ArchConfig):
    return {"norm": L.rms_norm_spec(cfg.d_model), "ssm": M.ssm_spec(cfg)}


def _apply_attn(p, cfg, x, positions, opts, return_cache=False):
    if cfg.attn_type == "mla":
        return L.mla_apply(p, cfg, x, positions, opts, return_cache)
    return L.gqa_apply(p, cfg, x, positions, opts, return_cache)


def _decode_attn(p, cfg, x, cache, pos, opts):
    if cfg.attn_type == "mla":
        out, c0, c1 = L.mla_decode(p, cfg, x, cache[0], cache[1], pos, opts)
    else:
        out, c0, c1 = L.gqa_decode(p, cfg, x, cache[0], cache[1], pos, opts)
    return out, (c0, c1)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


@dataclass
class LM:
    cfg: ArchConfig
    opts: AttnOptions = dataclasses.field(default_factory=AttnOptions)
    remat: bool = True
    ssm_backend: str = "xla"
    onehot_loss: bool = False      # vocab-parallel gold extraction (§Perf)
    moe_ep: bool = False           # expert-parallel a2a MoE (GShard; §Perf)
    moe_axes: Any = None           # explicit MoE shard axes (MRA per-tile K)
    kv_cache_dtype: Any = None     # e.g. jnp.int8: quantized decode cache
    # Per-layer PartitionSpec tree (block structure, no layer dim).  When
    # set, layer params are sharding-constrained at USE-SITE inside the
    # scan body; the transpose of that constraint pins the per-layer
    # gradient sharding too, so the backward scan reduce-scatters wgrads
    # instead of materializing them replicated (§Perf lever: memory + wire).
    block_pspecs: Any = None

    # ----------------------------------------------------------- param specs
    def param_specs(self):
        cfg = self.cfg
        out: Dict[str, Any] = {
            "embed": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
            "final_norm": L.rms_norm_spec(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = spec((cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"), init="small")
        fam = cfg.family
        if fam == "dense":
            out["blocks"] = _stack_specs(_dense_block_spec(cfg), cfg.n_layers)
        elif fam == "moe":
            n_moe = cfg.n_layers - cfg.n_dense_layers
            out["blocks"] = _stack_specs(_moe_block_spec(cfg), n_moe)
            if cfg.n_dense_layers:
                out["prelude"] = [
                    _dense_block_spec(cfg) for _ in range(cfg.n_dense_layers)]
        elif fam == "ssm":
            out["blocks"] = _stack_specs(_ssm_block_spec(cfg), cfg.n_layers)
        elif fam == "hybrid":
            out["blocks"] = _stack_specs(_ssm_block_spec(cfg), cfg.n_layers)
            out["shared_attn"] = _dense_block_spec(cfg)
        else:
            raise ValueError(fam)
        return out

    def init(self, key):
        return init_params(self.param_specs(), key)

    def abstract(self):
        return abstract_params(self.param_specs())

    # ------------------------------------------------------------- embedding
    def _embed(self, params, tokens=None, embeds=None):
        cfg = self.cfg
        if embeds is None:
            embeds = jnp.take(params["embed"], tokens, axis=0)
            if cfg.tie_embeddings:   # gemma-style scaling for tied embeddings
                embeds = embeds * jnp.asarray(np.sqrt(cfg.d_model), embeds.dtype)
        return shard_activation(embeds, DATA, None, None)

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["lm_head"]
        return shard_activation(logits.astype(jnp.float32), DATA, None,
                                MODEL_FULL)

    # ------------------------------------------------------- full-seq blocks
    def _block_fwd(self, bp, cfg, x, positions, want_cache: bool):
        """One block forward; returns (x, cache_or_None, aux)."""
        fam = cfg.family
        aux = jnp.zeros((), jnp.float32)
        if fam in ("dense", "moe") or bp.get("mlp") is not None:
            h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
            res = _apply_attn(bp["attn"], cfg, h, positions, self.opts,
                              return_cache=want_cache)
            h, cache = res if want_cache else (res, None)
            x = x + h
            h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
            if "moe" in bp:
                h, aux = MoE.moe_apply(bp["moe"], cfg, h, ep=self.moe_ep,
                                       model_axes=self.moe_axes)
            else:
                h = L.mlp_apply(bp["mlp"], h, cfg.act)
            x = x + h
            return x, cache, aux
        # ssm / hybrid backbone block
        h = L.rms_norm(x, bp["norm"], cfg.norm_eps)
        res = M.ssm_apply(bp["ssm"], cfg, h, backend=self.ssm_backend,
                          return_cache=want_cache)
        h, cache = res if want_cache else (res, None)
        return x + h, cache, aux

    def _shared_block_fwd(self, sp, cfg, x, positions, want_cache: bool):
        h = L.rms_norm(x, sp["attn_norm"], cfg.norm_eps)
        res = _apply_attn(sp["attn"], cfg, h, positions, self.opts,
                          return_cache=want_cache)
        h, cache = res if want_cache else (res, None)
        x = x + h
        h = L.rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp_apply(sp["mlp"], h, cfg.act)
        return x, cache

    # ------------------------------------------------------------ forward/LM
    def forward(self, params, tokens=None, embeds=None
                ) -> Tuple[jax.Array, jax.Array]:
        """Training/scoring forward.  Returns (logits f32, aux_loss)."""
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        for bp in params.get("prelude", []):
            x, _, _ = self._block_fwd(bp, cfg, x, positions, False)

        shared = params.get("shared_attn")
        every = cfg.shared_attn_every

        def body(carry, layer_in):
            x, aux, i = carry
            bp = layer_in
            if self.block_pspecs is not None:
                bp = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, bp, self.block_pspecs)
            if shared is not None and every:
                def with_shared(x):
                    y, _ = self._shared_block_fwd(shared, cfg, x, positions,
                                                  False)
                    return y
                x = jax.lax.cond(i % every == 0, with_shared, lambda x: x, x)
            x, _, a = self._block_fwd(bp, cfg, x, positions, False)
            return (x, aux + a, i + 1), None

        fn = jax.checkpoint(body) if self.remat else body
        (x, aux, _), _ = jax.lax.scan(
            fn, (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
            params["blocks"])
        n_scan = max(cfg.n_layers - cfg.n_dense_layers, 1)
        return self._logits(params, x), aux / n_scan

    # ----------------------------------------------------------------- loss
    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"))
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        if self.onehot_loss:
            # vocab-parallel gold extraction: iota==label compare stays
            # sharded over V (a gather would force an all-gather of the
            # full logits under GSPMD) — §Perf hillclimb lever
            V = logits.shape[-1]
            hit = labels[..., None] == jax.lax.broadcasted_iota(
                jnp.int32, (1, 1, V), 2)
            gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
        else:
            gold = jnp.take_along_axis(logits, labels[..., None],
                                       axis=-1)[..., 0]
        nll = jnp.mean(logz - gold)
        loss = nll + 0.01 * aux
        return loss, {"nll": nll, "aux": aux}

    # -------------------------------------------------------------- prefill
    def prefill(self, params, tokens=None, embeds=None, cache_len: int = 0):
        """Full-sequence forward that also builds the decode cache.

        Returns (last-token logits (B,V), cache).  ``cache_len`` pads the KV
        cache to the serving window (default: the prompt length).
        """
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        B, S, _ = x.shape
        W = self._window(cache_len or S)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        cache: Dict[str, Any] = {"pos": jnp.asarray(S, jnp.int32)}
        pre_caches = []
        for bp in params.get("prelude", []):
            x, c, _ = self._block_fwd(bp, cfg, x, positions, True)
            pre_caches.append(self._pad_attn_cache(c, W, S))
        if pre_caches:
            cache["prelude"] = pre_caches

        shared = params.get("shared_attn")
        every = cfg.shared_attn_every
        n_apps = -(-cfg.n_layers // every) if (shared is not None and every) else 0

        def body(carry, bp):
            x, i, sh_stack = carry
            if n_apps:
                # the shared tile keeps one KV history PER application site
                def with_shared(operand):
                    x, stack = operand
                    y, c = self._shared_block_fwd(shared, cfg, x, positions,
                                                  True)
                    app = i // every
                    stack = jax.tree_util.tree_map(
                        lambda s, n: jax.lax.dynamic_update_index_in_dim(
                            s, n.astype(s.dtype), app, 0), stack, c)
                    return y, stack
                x, sh_stack = jax.lax.cond(
                    i % every == 0, with_shared, lambda o: o, (x, sh_stack))
            x, c, _ = self._block_fwd(bp, cfg, x, positions, True)
            return (x, i + 1, sh_stack), c

        sh0 = None
        if n_apps:
            one = self._zero_attn_cache(B, S, dtype=x.dtype)
            sh0 = jax.tree_util.tree_map(
                lambda a: jnp.zeros((n_apps,) + a.shape, a.dtype), one)
        (x, _, sh_stack), block_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.int32), sh0), params["blocks"])

        if cfg.family in ("ssm", "hybrid"):
            cache["blocks"] = block_caches          # no sequence axis
        else:
            cache["blocks"] = self._pad_attn_cache(block_caches, W, S)
        if sh0 is not None:
            cache["shared_attn"] = self._pad_attn_cache(sh_stack, W, S)
        logits = self._logits(params, x[:, -1:, :])[:, 0, :]
        return logits, cache

    # ---------------------------------------------------------- decode step
    def decode_step(self, params, cache, tokens=None, embeds=None):
        """One-token decode.  tokens: (B,1) (or embeds (B,1,d)).

        Returns (logits (B,V), new_cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        pos = cache["pos"]
        new_cache: Dict[str, Any] = {"pos": pos + 1}

        if "prelude" in cache:
            pcs = []
            for bp, c in zip(params["prelude"], cache["prelude"]):
                x, c2 = self._block_decode(bp, cfg, x, c, pos)
                pcs.append(c2)
            new_cache["prelude"] = pcs

        shared = params.get("shared_attn")
        every = cfg.shared_attn_every
        sh_cache = cache.get("shared_attn")

        def body(carry, layer_in):
            x, i, sh_stack = carry
            bp, c = layer_in
            if shared is not None and every:
                def with_shared(operand):
                    x, stack = operand
                    app = i // every
                    sc = jax.tree_util.tree_map(
                        lambda s: jax.lax.dynamic_index_in_dim(
                            s, app, 0, keepdims=False), stack)
                    h = L.rms_norm(x, shared["attn_norm"], cfg.norm_eps)
                    h, sc2 = _decode_attn(shared["attn"], cfg, h, sc, pos,
                                          self.opts)
                    x = x + h
                    h = L.rms_norm(x, shared["mlp_norm"], cfg.norm_eps)
                    x = x + L.mlp_apply(shared["mlp"], h, cfg.act)
                    stack = jax.tree_util.tree_map(
                        lambda s, n: jax.lax.dynamic_update_index_in_dim(
                            s, n.astype(s.dtype), app, 0), stack, sc2)
                    return x, stack
                x, sh_stack = jax.lax.cond(i % every == 0, with_shared,
                                           lambda o: o, (x, sh_stack))
            x, c2 = self._block_decode(bp, cfg, x, c, pos)
            return (x, i + 1, sh_stack), c2

        (x, _, sh_cache), blk = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.int32), sh_cache),
            (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = blk
        if sh_cache is not None:
            new_cache["shared_attn"] = sh_cache
        logits = self._logits(params, x)[:, 0, :]
        return logits, new_cache

    def _block_decode(self, bp, cfg, x, c, pos):
        aux = None
        if "ssm" in bp:
            h = L.rms_norm(x, bp["norm"], cfg.norm_eps)
            h, c2 = M.ssm_decode(bp["ssm"], cfg, h, c)
            return x + h, c2
        h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
        h, c2 = _decode_attn(bp["attn"], cfg, h, c, pos, self.opts)
        x = x + h
        h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
        if "moe" in bp:
            h, _ = MoE.moe_apply(bp["moe"], cfg, h, ep=self.moe_ep,
                                 model_axes=self.moe_axes)
        else:
            h = L.mlp_apply(bp["mlp"], h, cfg.act)
        return x + h, c2

    # ------------------------------------------------------------ cache mgmt
    def _window(self, requested: int) -> int:
        """Serving KV window: SWA archs cap at the sliding window."""
        cfg = self.cfg
        if cfg.sliding_window:
            return min(requested, cfg.sliding_window)
        return requested

    def _attn_cache_dims(self):
        cfg = self.cfg
        if cfg.attn_type == "mla":
            return (cfg.kv_lora_rank,), (cfg.qk_rope_dim,)
        return (cfg.n_kv_heads, cfg.head_dim), (cfg.n_kv_heads, cfg.head_dim)

    def _zero_attn_cache(self, B, W, dtype=jnp.bfloat16, padded=True):
        d0, d1 = self._attn_cache_dims()
        return (jnp.zeros((B, W) + d0, dtype), jnp.zeros((B, W) + d1, dtype))

    def _pad_attn_cache(self, c, W: int, S: int):
        """Fit prefill-produced caches (len S) into the serving window W."""
        if c is None:
            return None
        def fit(a):
            if a is None:
                return None
            # prefill caches come as (B,S,*tail) or stacked (L,B,S,*tail);
            # locate the sequence axis (first axis of size S after axis 0)
            ax = None
            for i in range(1, a.ndim):
                if a.shape[i] == S:
                    ax = i
                    break
            assert ax is not None, (a.shape, S)
            if W == S:
                return a
            if W < S:
                # keep the last W positions AND rotate them so position p
                # lands in ring slot p % W (decode's slot = pos % W)
                idx = [slice(None)] * a.ndim
                idx[ax] = slice(S - W, S)
                kept = a[tuple(idx)]
                return jnp.roll(kept, shift=(S - W) % W, axis=ax)
            pad = [(0, 0)] * a.ndim
            pad[ax] = (0, W - S)
            return jnp.pad(a, pad)
        return jax.tree_util.tree_map(fit, c)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        """Empty decode cache sized for ``max_len`` context."""
        cfg = self.cfg
        dtype = dtype or self.kv_cache_dtype or jnp.bfloat16
        W = self._window(max_len)
        cache: Dict[str, Any] = {"pos": jnp.asarray(0, jnp.int32)}
        if cfg.family in ("ssm", "hybrid"):
            one = M.ssm_cache_init(cfg, batch, dtype)
            cache["blocks"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
                one)
            if cfg.family == "hybrid" and cfg.shared_attn_every:
                n_apps = -(-cfg.n_layers // cfg.shared_attn_every)
                one = self._zero_attn_cache(batch, W, dtype)
                cache["shared_attn"] = jax.tree_util.tree_map(
                    lambda a: jnp.zeros((n_apps,) + a.shape, a.dtype), one)
            return cache
        n_scan = cfg.n_layers - cfg.n_dense_layers
        one = self._zero_attn_cache(batch, W, dtype)
        cache["blocks"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_scan,) + a.shape), one)
        if cfg.n_dense_layers:
            cache["prelude"] = [self._zero_attn_cache(batch, W, dtype)
                                for _ in range(cfg.n_dense_layers)]
        return cache
