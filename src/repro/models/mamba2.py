"""Mamba-2 (SSD, state-space duality) mixer — chunked scan + decode step.

The SSD chunked algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence
into chunks of Q tokens: a quadratic *intra-chunk* term (MXU-friendly block
matmuls — this is the Pallas kernel target, kernels/ssd_scan.py) and a linear
*inter-chunk* state recurrence (lax.scan).  Decode carries (conv, state)
caches and is O(1) per token — this is what makes ``long_500k`` runnable for
the ssm/hybrid architectures.

Projections are split (w_z/w_x/w_B/w_C/w_dt + per-stream depthwise convs)
rather than fused, which is mathematically identical to the fused in_proj
but gives each stream a clean TP sharding (d_inner over ``model``; the
B/C state streams replicated, matching g=1 shared groups).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import spec, shard_activation
from repro.models.layers import rms_norm, rms_norm_spec, DATA, MODEL


def ssm_spec(cfg: ArchConfig):
    d, di, st, nh, c = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.n_ssm_heads, cfg.ssm_conv)
    g = cfg.ssm_ngroups
    return {
        "w_z": spec((d, di), ("embed", "d_inner")),
        "w_x": spec((d, di), ("embed", "d_inner")),
        "w_B": spec((d, g * st), ("embed", "ssm_state")),
        "w_C": spec((d, g * st), ("embed", "ssm_state")),
        "w_dt": spec((d, nh), ("embed", "ssm_heads")),
        "conv_x": spec((c, di), (None, "d_inner"), init="normal", scale=0.5),
        "conv_B": spec((c, g * st), (None, "ssm_state"), init="normal", scale=0.5),
        "conv_C": spec((c, g * st), (None, "ssm_state"), init="normal", scale=0.5),
        "dt_bias": spec((nh,), ("ssm_heads",), dtype=jnp.float32, init="zeros"),
        "A_log": spec((nh,), ("ssm_heads",), dtype=jnp.float32, init="zeros"),
        "D": spec((nh,), ("ssm_heads",), dtype=jnp.float32, init="ones"),
        "norm": rms_norm_spec(di),
        "out_proj": spec((di, d), ("d_inner", "embed"), init="small"),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  x: (B,L,C), w: (c,C)."""
    c = w.shape[0]
    out = x * w[-1]
    for i in range(1, c):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i, :]
        out = out + shifted * w[-1 - i]
    return out


def _conv_step(x_t: jax.Array, buf: jax.Array, w: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Single-token depthwise conv.  x_t: (B,C); buf: (B,c-1,C) past inputs."""
    hist = jnp.concatenate([buf, x_t[:, None, :]], axis=1)   # (B,c,C)
    out = jnp.einsum("btc,tc->bc", hist, w)
    return out, hist[:, 1:, :]


def _ssd_inputs(p: Dict, cfg: ArchConfig, x: jax.Array):
    """Shared projections for scan/decode.  x: (B,L,d)."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bm = x @ p["w_B"]
    Cm = x @ p["w_C"]
    dt = (x @ p["w_dt"]).astype(jnp.float32)
    return z, xs, Bm, Cm, dt


def ssd_scan_ref(xs, dt, A, Bm, Cm, D, chunk: int):
    """Chunked SSD.  xs:(B,L,nh,hd) f32, dt:(B,L,nh) f32 (post-softplus),
    A:(nh,) f32 (negative), Bm/Cm:(B,L,st) f32 (g=1 shared), D:(nh,).
    Returns (y:(B,L,nh,hd) f32, h_final:(B,nh,st,hd) f32).
    """
    Bb, L, nh, hd = xs.shape
    st = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    xc = xs.reshape(Bb, nc, Q, nh, hd)
    dtc = dt.reshape(Bb, nc, Q, nh)
    Bc = Bm.reshape(Bb, nc, Q, st)
    Cc = Cm.reshape(Bb, nc, Q, st)

    log_a = dtc * A                                        # (b,nc,q,nh) <= 0
    la = jnp.cumsum(log_a, axis=2)                         # within-chunk cumsum
    la_last = la[:, :, -1:, :]                             # (b,nc,1,nh)

    # --- intra-chunk (quadratic, the Pallas kernel target) ----------------
    # decay L_ij = exp(la_i - la_j) for i >= j
    diff = la[:, :, :, None, :] - la[:, :, None, :, :]     # (b,nc,i,j,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)         # (b,nc,i,j)
    att = scores[..., None] * Lmat * dtc[:, :, None, :, :]  # (b,nc,i,j,nh)
    y_intra = jnp.einsum("bcijn,bcjnh->bcinh", att, xc)

    # --- chunk summary states ---------------------------------------------
    w = jnp.exp(la_last - la) * dtc                        # (b,nc,q,nh)
    S = jnp.einsum("bcjn,bcjs,bcjnh->bcnsh", w, Bc, xc)    # (b,nc,nh,st,hd)

    # --- inter-chunk recurrence --------------------------------------------
    def step(h, inputs):
        S_c, la_c, la_last_c, C_c = inputs
        # contribution of the incoming state to every position in the chunk
        y_in = jnp.einsum("bis,bnsh,bin->binh", C_c, h, jnp.exp(la_c))
        h = h * jnp.exp(la_last_c)[:, 0, :, None, None] + S_c
        return h, y_in

    h0 = jnp.zeros((Bb, nh, st, hd), jnp.float32)
    h_final, y_inter = jax.lax.scan(
        step, h0,
        (S.swapaxes(0, 1), la.swapaxes(0, 1), la_last.swapaxes(0, 1),
         Cc.swapaxes(0, 1)))
    y_inter = y_inter.swapaxes(0, 1).reshape(Bb, nc, Q, nh, hd)

    y = y_intra + y_inter + xc * D[None, None, None, :, None]
    return y.reshape(Bb, L, nh, hd), h_final


def ssm_apply(p: Dict, cfg: ArchConfig, x: jax.Array,
              backend: str = "xla", return_cache: bool = False):
    """Full-sequence Mamba-2 block.  x: (B,L,d) -> (B,L,d) [, cache]."""
    Bb, L, d = x.shape
    nh, hd, st = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    c = cfg.ssm_conv
    z, xs, Bm, Cm, dt = _ssd_inputs(p, cfg, x)
    xs_raw, Bm_raw, Cm_raw = xs, Bm, Cm                    # pre-conv (cache tails)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"]))
    xs = shard_activation(xs, DATA, None, MODEL)

    dt = jax.nn.softplus(dt + p["dt_bias"])                # (B,L,nh)
    A = -jnp.exp(p["A_log"])                               # (nh,)

    # pad to a chunk multiple; padded positions get dt=0 so they neither
    # emit output nor perturb the carried state (a = exp(0*A) = 1, upd = 0)
    Q = min(cfg.ssm_chunk, max(L, 1))
    Lp = -(-L // Q) * Q
    if Lp != L:
        padw = ((0, 0), (0, Lp - L), (0, 0))
        xs = jnp.pad(xs, padw)
        Bm, Cm = jnp.pad(Bm, padw), jnp.pad(Cm, padw)
        dt = jnp.pad(dt, padw)
    xsh = xs.reshape(Bb, Lp, nh, hd).astype(jnp.float32)
    if backend == "pallas":
        from repro.kernels import ops as kops
        y, h_final = kops.ssd_scan(xsh, dt, A, Bm.astype(jnp.float32),
                                   Cm.astype(jnp.float32), p["D"],
                                   chunk=cfg.ssm_chunk)
    else:
        y, h_final = ssd_scan_ref(xsh, dt, A, Bm.astype(jnp.float32),
                                  Cm.astype(jnp.float32), p["D"],
                                  chunk=cfg.ssm_chunk)
    y = y.reshape(Bb, Lp, nh * hd)[:, :L, :].astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    y = shard_activation(y, DATA, None, MODEL)
    out = y @ p["out_proj"]
    if not return_cache:
        return out
    cache = dict(conv_x=xs_raw[:, L - (c - 1):, :],
                 conv_B=Bm_raw[:, L - (c - 1):, :],
                 conv_C=Cm_raw[:, L - (c - 1):, :],
                 state=h_final)
    return out, cache


def ssm_decode(p: Dict, cfg: ArchConfig, x: jax.Array, cache: Dict
               ) -> Tuple[jax.Array, Dict]:
    """Single-token decode.  x: (B,1,d); cache keys: conv_x/conv_B/conv_C
    (B,c-1,·) and state (B,nh,st,hd).  O(1) in context length."""
    Bb = x.shape[0]
    nh, hd, st = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xs, Bm, Cm, dt = _ssd_inputs(p, cfg, x[:, 0:1, :])
    z, xs, Bm, Cm, dt = z[:, 0], xs[:, 0], Bm[:, 0], Cm[:, 0], dt[:, 0]

    xs, conv_x = _conv_step(xs, cache["conv_x"], p["conv_x"])
    Bm, conv_B = _conv_step(Bm, cache["conv_B"], p["conv_B"])
    Cm, conv_C = _conv_step(Cm, cache["conv_C"], p["conv_C"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt + p["dt_bias"])                # (B,nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                    # (B,nh)
    xh = xs.reshape(Bb, nh, hd).astype(jnp.float32)
    # state update: h = a h + dt * B (outer) x
    upd = jnp.einsum("bn,bs,bnh->bnsh", dt, Bm.astype(jnp.float32), xh)
    h = cache["state"] * a[:, :, None, None] + upd
    y = jnp.einsum("bs,bnsh->bnh", Cm.astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bb, nh * hd).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = dict(conv_x=conv_x, conv_B=conv_B, conv_C=conv_C, state=h)
    return out, new_cache


def ssm_cache_init(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    c = cfg.ssm_conv
    g = cfg.ssm_ngroups
    return dict(
        conv_x=jnp.zeros((batch, c - 1, cfg.d_inner), dtype),
        conv_B=jnp.zeros((batch, c - 1, g * cfg.ssm_state), dtype),
        conv_C=jnp.zeros((batch, c - 1, g * cfg.ssm_state), dtype),
        state=jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_state,
                         cfg.ssm_headdim), jnp.float32),
    )
