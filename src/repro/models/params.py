"""Parameter-spec machinery.

A model is described once as a pytree of :class:`ParamSpec` (shape, dtype,
logical axis names, initializer).  From that single tree we derive:

* ``init_params``     — materialized weights (PRNG-seeded),
* ``abstract_params`` — ``jax.ShapeDtypeStruct`` stand-ins (dry-run, no alloc),
* ``logical_axes``    — pytree of logical-axis tuples,
* ``shardings``       — pytree of ``NamedSharding`` after applying rules.

Logical→mesh rules implement the Vespa tile plan: the baseline maps model
dimensions to the ``model`` mesh axis; MRA replication (paper C1) remaps a
tile's logical axes onto the ``(replica, shard)`` factoring without touching
the ParamSpec tree — the "accelerator RTL" never changes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import get_abstract_mesh as _ambient_mesh

Axis = Optional[Union[str, Tuple[str, ...]]]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis name per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                     # normal | zeros | ones | small
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, dtype=jnp.bfloat16, init="normal", scale=0.02) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract_params(tree):
    return _tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def logical_axes(tree):
    return _tree_map(lambda s: s.axes, tree)


def _init_one(s: ParamSpec, key) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    scale = s.scale
    if s.init == "small":
        scale = s.scale / max(1, int(np.sqrt(np.prod(s.shape[:-1]) or 1)))
    x = jax.random.normal(key, s.shape, jnp.float32) * scale
    return x.astype(s.dtype)


def init_params(tree, key):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Logical → mesh rules
# ---------------------------------------------------------------------------

# Baseline rule set for the ("data", "model") production mesh.  Tuples mean
# "sharded over multiple mesh axes".  ``None`` = replicated.
BASE_RULES: Dict[str, Axis] = {
    "layers": None,
    "vocab": "model",
    "embed": None,
    "qkv": "model",          # flattened n_heads*head_dim projection dim
    "kv": "model",           # flattened n_kv_heads*head_dim projection dim
    "heads": "model",
    "ff": "model",
    "ff_in": None,
    "experts": None,         # baseline: expert-TP (shard expert_ff), EP is a variant
    "expert_ff": "model",
    "kv_lora": None,
    "d_inner": "model",      # mamba inner channels
    "ssm_state": None,
    "ssm_heads": "model",
    "conv_ch": "model",
    "norm": None,
}


def rules_with(overrides: Dict[str, Axis]) -> Dict[str, Axis]:
    r = dict(BASE_RULES)
    r.update(overrides)
    return r


def mesh_axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def partition_spec_for(axes: Tuple[Optional[str], ...],
                       shape: Tuple[int, ...],
                       rules: Dict[str, Axis],
                       mesh: Mesh) -> P:
    """Map logical axes to a PartitionSpec, replicating when not divisible."""
    entries = []
    used: set = set()
    for name, dim in zip(axes, shape):
        ax = rules.get(name) if name is not None else None
        if ax is None:
            entries.append(None)
            continue
        axt = ax if isinstance(ax, tuple) else (ax,)
        if any(a in used for a in axt):
            entries.append(None)        # an axis can shard only one dim
            continue
        if dim % mesh_axis_size(mesh, ax) != 0:
            entries.append(None)        # replicate non-divisible dims
            continue
        used.update(axt)
        entries.append(ax)
    return P(*entries)


def shardings_for(tree, rules: Dict[str, Axis], mesh: Mesh):
    def one(s: ParamSpec):
        return NamedSharding(mesh, partition_spec_for(s.axes, s.shape, rules, mesh))
    return _tree_map(one, tree)


def pspecs_for(tree, rules: Dict[str, Axis], mesh: Mesh):
    def one(s: ParamSpec):
        return partition_spec_for(s.axes, s.shape, rules, mesh)
    return _tree_map(one, tree)


# ---------------------------------------------------------------------------
# Activation sharding helper
# ---------------------------------------------------------------------------


# Batch ("stream") axes are swappable at lowering time: the baseline maps
# batch dims to ("pod", "data"); the FSDP strategy adds "model"; an MRA mesh
# adds "replica" (the AXI bridge splits the stream across tile replicas).
_DEFAULT_BATCH_AXES: Tuple[str, ...] = ("pod", "data")
_BATCH_AXES: Tuple[str, ...] = _DEFAULT_BATCH_AXES


def set_batch_axes(axes: Tuple[str, ...]) -> None:
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def get_batch_axes() -> Tuple[str, ...]:
    return _BATCH_AXES


def shard_activation(x: jax.Array, *axes: Axis) -> jax.Array:
    """``with_sharding_constraint`` that degrades to no-op without a mesh.

    ``axes`` is a per-dim mesh-axis assignment (None = unconstrained).  Safe
    to call from model code unconditionally; under a 1-device test mesh or no
    mesh at all it's the identity.  Any axis equal to the default batch-axes
    tuple is substituted with the currently-configured batch axes.
    """
    axes = tuple(_BATCH_AXES if a == _DEFAULT_BATCH_AXES else a
                 for a in axes)
    try:
        _names = set(_ambient_mesh().axis_names)
    except Exception:                                    # pragma: no cover
        _names = set()
    if "model" not in _names and "shard" in _names:
        # MRA-factored mesh: intra-tile model dims live on the "shard"
        # sub-axis; K=1 tiles (MODEL_FULL, e.g. the vocab tile) span both —
        # so "replica" must vacate the batch dims of those tensors
        if "__model_full__" in axes:
            axes = tuple(
                tuple(n for n in a if n != "replica") if isinstance(a, tuple)
                else a for a in axes)
        axes = tuple("shard" if a == "model" else a for a in axes)
        axes = tuple(("replica", "shard") if a == "__model_full__" else a
                     for a in axes)
    else:
        axes = tuple("model" if a == "__model_full__" else a for a in axes)
    try:
        am = _ambient_mesh()
    except Exception:                                    # pragma: no cover
        return x
    if am is None or not getattr(am, "axis_names", ()):  # no mesh context
        return x
    names = set(am.axis_names)
    ents = []
    for a in axes[: x.ndim]:
        if a is None:
            ents.append(None)
        elif isinstance(a, tuple):
            present = tuple(n for n in a if n in names)
            ents.append(present if present else None)
        else:
            ents.append(a if a in names else None)
    ents += [None] * (x.ndim - len(ents))
    # drop constraints that don't divide or reuse an axis (first dim wins —
    # matters when the batch axes absorb "model" under the FSDP strategy)
    fixed = []
    used: set = set()
    for dim, a in zip(x.shape, ents):
        if a is None:
            fixed.append(None)
            continue
        names_a = list(a) if isinstance(a, tuple) else [a]
        names_a = [n for n in names_a if n not in used]
        # drop trailing axes until this dim divides (multi-pod FSDP with
        # global_batch < chips falls back to fewer batch axes)
        while names_a:
            size = 1
            for n in names_a:
                size *= am.shape[n]
            if dim % size == 0:
                break
            names_a.pop()
        if names_a:
            ent = tuple(names_a) if len(names_a) > 1 else names_a[0]
            fixed.append(ent)
            used.update(names_a)
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    total = 0
    for l in leaves:
        shape = l.shape
        total += int(np.prod(shape)) if len(shape) else 1
    return total
