"""Mixture-of-Experts FFN: dropless sort + ``jax.lax.ragged_dot`` dispatch.

TPU adaptation notes (DESIGN.md §2): GPU MoE kernels scatter tokens with
atomics; the TPU-idiomatic form is sort-by-expert + grouped matmul
(``ragged_dot``), which keeps the MXU busy on contiguous tiles.

Sharding: tokens are data-parallel, experts are **expert-TP** in the
baseline — every expert's FFN is sharded over the ``model`` axis on the
d_ff dim, so MoE comms equal dense-MLP comms (one psum).  Routing/sort stays
*local* to each data shard by construction (shard_map), avoiding a global
sort.  Expert-parallel all-to-all dispatch is the Vespa-MRA variant
(core/replication.py) explored in §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.params import spec, get_batch_axes
from repro.models.layers import _act, DATA, MODEL

from repro.compat import get_abstract_mesh as _get_abstract_mesh
from repro.compat import shard_map as _shard_map

P = jax.sharding.PartitionSpec


def moe_spec(cfg: ArchConfig):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    out = {
        "router": spec((d, E), ("embed", "experts"), dtype=jnp.float32),
        "wi_gate": spec((E, d, f), ("experts", "embed", "expert_ff")),
        "wi_up": spec((E, d, f), ("experts", "embed", "expert_ff")),
        "wo": spec((E, f, d), ("experts", "expert_ff", "embed"), init="small"),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        out["shared"] = {
            "wi_gate": spec((d, fs), ("embed", "ff")),
            "wi_up": spec((d, fs), ("embed", "ff")),
            "wo": spec((fs, d), ("ff", "embed"), init="small"),
        }
    return out


def _route(router_w: jax.Array, x: jax.Array, top_k: int):
    """Token->expert assignment.  x: (N,d).  Returns gates (N,k) f32, ids (N,k)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)   # (N,E)
    top_logits, top_ids = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_logits, axis=-1)
    return gates, top_ids, logits


def _moe_ffn_local(p: Dict, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Per-shard dropless MoE.  x: (N,d) local tokens; expert weights are the
    local d_ff shard.  Returns (out (N,d) [partial over model axis], aux loss).
    """
    N, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    gates, top_ids, logits = _route(p["router"], x, k)

    # flatten (token, slot) pairs and sort by expert
    flat_ids = top_ids.reshape(-1)                        # (N*k,)
    sort_idx = jnp.argsort(flat_ids)                      # stable
    tok_idx = sort_idx // k                               # token of each row
    xs = jnp.take(x, tok_idx, axis=0)                     # (N*k, d)
    group_sizes = jnp.bincount(flat_ids, length=E).astype(jnp.int32)

    h = _act(jax.lax.ragged_dot(xs, p["wi_gate"], group_sizes), cfg.act)
    h = h * jax.lax.ragged_dot(xs, p["wi_up"], group_sizes)
    ys = jax.lax.ragged_dot(h, p["wo"], group_sizes)      # (N*k, d)

    gate_sorted = jnp.take(gates.reshape(-1), sort_idx, axis=0)
    ys = ys * gate_sorted[:, None].astype(ys.dtype)
    out = jnp.zeros((N, d), ys.dtype).at[tok_idx].add(ys)

    # Switch-style load-balance aux loss (fraction * probability per expert)
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_ids, E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0)) * k
    return out, aux


def _moe_ep_shard(pp: Dict, x: jax.Array, cfg: ArchConfig, *,
                  model_axis: str, capacity: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """GShard-style expert-parallel MoE body (runs under shard_map).

    Experts are sharded on the EXPERT dim over ``model_axis`` (each shard
    owns E/m complete experts); tokens are sharded over every mesh axis.
    Dispatch = capacity-bounded all-to-all (cf. ``cfg.capacity_factor``;
    overflowing (token, expert) assignments are dropped, GShard semantics);
    combine = the mirror all-to-all + gate-weighted scatter-add at origin.

    Wire bytes per device ≈ 4 · n_local · k · cf · d · dtype per layer
    (dispatch+combine, fwd+bwd) — independent of the expert count and ~16x
    less than replicated-token expert-TP at production shapes (§Perf B).
    """
    m = jax.lax.axis_size(model_axis)
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // m
    n, d = x.shape
    C = capacity

    gates, top_ids, logits = _route(pp["router"], x, k)    # router replicated
    flat_ids = top_ids.reshape(-1)                         # (n*k,)
    dest = flat_ids // E_loc                               # owning shard
    # slot within the destination bucket, first-come order (GShard priority)
    onehot = jax.nn.one_hot(dest, m, dtype=jnp.int32)      # (n*k, m)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
    keep = pos < C
    slot = dest * C + pos                                  # flat send slot
    oob = m * C                                            # drop target
    slot = jnp.where(keep, slot, oob)

    tok_of_row = jnp.arange(n * k, dtype=jnp.int32) // k
    x_rows = jnp.take(x, tok_of_row, axis=0)               # (n*k, d)
    send = jnp.zeros((m * C, d), x.dtype).at[slot].set(x_rows, mode="drop")
    send_eid = jnp.zeros((m * C,), jnp.int32).at[slot].set(
        flat_ids % E_loc, mode="drop")                     # zero rows -> e0,
    #                                   harmless: zero inputs yield zero out

    recv = jax.lax.all_to_all(send.reshape(m, C, d), model_axis, 0, 0,
                              tiled=False).reshape(m * C, d)
    eids = jax.lax.all_to_all(send_eid.reshape(m, C), model_axis, 0, 0,
                              tiled=False).reshape(m * C)

    # grouped expert FFN over the received rows
    sort_idx = jnp.argsort(eids)
    rows = jnp.take(recv, sort_idx, axis=0)
    gs = jnp.bincount(eids, length=E_loc).astype(jnp.int32)
    h = _act(jax.lax.ragged_dot(rows, pp["wi_gate"], gs), cfg.act)
    h = h * jax.lax.ragged_dot(rows, pp["wi_up"], gs)
    y = jax.lax.ragged_dot(h, pp["wo"], gs)                # (m*C, d)
    y = jnp.zeros_like(y).at[sort_idx].set(y)              # unsort to slots

    back = jax.lax.all_to_all(y.reshape(m, C, d), model_axis, 0, 0,
                              tiled=False).reshape(m * C, d)
    y_rows = jnp.take(back, jnp.minimum(slot, m * C - 1), axis=0)
    y_rows = jnp.where(keep[:, None], y_rows, 0.0)
    w = (gates.reshape(-1) * keep).astype(y_rows.dtype)
    out = jnp.zeros((n, d), y_rows.dtype).at[tok_of_row].add(
        y_rows * w[:, None])

    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_ids, E, dtype=jnp.float32),
                    axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0)) * k
    return out, aux


def moe_apply(p: Dict, cfg: ArchConfig, x: jax.Array,
              mesh: Optional[jax.sharding.AbstractMesh] = None,
              ep: bool = False, model_axes=None) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN over (B,S,d).  Uses shard_map when a mesh is ambient so that
    routing+sort stay shard-local; single-device path otherwise."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)

    if mesh is None:
        try:
            mesh = _get_abstract_mesh()
        except Exception:  # pragma: no cover
            mesh = None
    names = tuple(getattr(mesh, "axis_names", ()) or ())
    routed_p = {k: v for k, v in p.items() if k != "shared"}
    # the f/expert shard axis: "model" on the production mesh, "shard" on an
    # MRA-factored mesh (where "replica" carries the batch stream)
    MX = MODEL if MODEL in names else ("shard" if "shard" in names else None)
    if model_axes is not None:                 # explicit (MRA per-tile K=1)
        MX = model_axes

    def _mx_size():
        if isinstance(MX, tuple):
            return int(np.prod([mesh.shape[a] for a in MX]))
        return mesh.shape[MX]

    if names and MX and ep and not isinstance(MX, tuple) \
            and cfg.n_experts % _mx_size() == 0:
        # expert-parallel: experts sharded on the expert dim; tokens sharded
        # over EVERY axis; capacity-bounded all-to-all dispatch (GShard)
        dp = tuple(a for a in get_batch_axes() if a in names and a != MX)
        all_axes = dp + (MX,)
        n_shards = 1
        for a in all_axes:
            n_shards *= mesh.shape[a]
        if (B * S) % n_shards == 0:
            m = mesh.shape[MX]
            n_loc = (B * S) // n_shards
            capacity = max(1, int(np.ceil(n_loc * cfg.top_k / m
                                          * cfg.capacity_factor)))
            ep_specs = {
                "router": P(None, None),
                "wi_gate": P(MX, None, None),
                "wi_up": P(MX, None, None),
                "wo": P(MX, None, None),
            }

            def ep_body(pp, xx):
                out, aux = _moe_ep_shard(pp, xx, cfg, model_axis=MX,
                                         capacity=capacity)
                aux = jax.lax.pmean(aux, all_axes)
                return out, aux

            # pin boundary shardings so GSPMD propagation outside can't
            # hand the shard_map an unnameable tiling
            routed_c = {k: jax.lax.with_sharding_constraint(v, ep_specs[k])
                        for k, v in routed_p.items()}
            xf_c = jax.lax.with_sharding_constraint(xf, P(all_axes, None))
            out, aux = _shard_map(
                ep_body, mesh=mesh,
                in_specs=({k: ep_specs[k] for k in routed_p},
                          P(all_axes, None)),
                out_specs=(P(all_axes, None), P()),
            )(routed_c, xf_c)
            out = out.reshape(B, S, d)
            # re-pin after the reshape: the (dp·model)-sharded token dim
            # splitting into (B, S) can otherwise leave an un-nameable tiling
            if B % (n_shards // mesh.shape[MX]) == 0:
                from repro.models.params import shard_activation
                out = shard_activation(out, DATA, None, None)
            if cfg.n_shared_experts:
                sp = p["shared"]
                gate = _act(x @ sp["wi_gate"], cfg.act)
                out = out + (gate * (x @ sp["wi_up"])) @ sp["wo"]
            return out, aux

    if names and MX:
        mx_set = set(MX) if isinstance(MX, tuple) else {MX}
        dp = tuple(a for a in get_batch_axes()
                   if a in names and a not in mx_set)
        tok = dp if dp else None
        specs = {
            "router": P(None, None),
            "wi_gate": P(None, None, MX),
            "wi_up": P(None, None, MX),
            "wo": P(None, MX, None),
        }

        def body(pp, xx):
            out, aux = _moe_ffn_local(pp, xx, cfg)
            out = jax.lax.psum(out, MX)
            aux = jax.lax.pmean(aux, MX)
            if dp:
                aux = jax.lax.pmean(aux, dp)
            return out, aux

        out, aux = _shard_map(
            body, mesh=mesh,
            in_specs=({k: specs[k] for k in routed_p}, P(tok, None)),
            out_specs=(P(tok, None), P()),
        )(routed_p, xf)
    else:
        out, aux = _moe_ffn_local(routed_p, xf, cfg)

    out = out.reshape(B, S, d)
    if cfg.n_shared_experts:
        sp = p["shared"]
        gate = _act(x @ sp["wi_gate"], cfg.act)
        out = out + (gate * (x @ sp["wi_up"])) @ sp["wo"]
    return out, aux
