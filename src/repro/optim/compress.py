"""Gradient compression for the interconnect island (distributed-opt trick).

Cross-pod gradient reduction is the longest-haul traffic in the production
mesh (the ``pod`` axis models the inter-pod/DCN hop).  When the NoC island's
DFS rate is lowered — or when the fabric is the measured bottleneck — the
runtime can switch the pod-axis reduction to int8:

    q = round(g / scale) : int8, scale = max|g| / 127 per leaf
    all_gather(q, 'pod') -> dequant + sum in f32

Wire bytes drop 4x vs f32 (2x vs bf16) at a quantization error that a
per-leaf scale keeps below ~1% of the gradient norm (tests/test_optim.py
asserts this).  This is precision-island switching — a Vespa DFS actuator
lever, not just an optimizer flag (DESIGN.md §C2 actuator list).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map as _shard_map

P = jax.sharding.PartitionSpec


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g: jax.Array, axis: str) -> jax.Array:
    """int8 all-gather + f32 sum over one mesh axis; call under shard_map."""
    q, scale = quantize_int8(g)
    qs = jax.lax.all_gather(q, axis)               # (n, ...)
    ss = jax.lax.all_gather(scale, axis)           # (n,)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim)
    return jnp.sum(deq, axis=0).astype(g.dtype)


def compressed_allreduce(grads: Any, mesh, axis: str = "pod") -> Any:
    """Compress-reduce a *pod-sharded partial* gradient pytree over ``axis``.

    Expects grads whose values are per-pod partial sums (e.g. produced under
    shard_map with no psum over the pod axis); returns fully-summed grads.
    """
    def body(g):
        return jax.tree_util.tree_map(
            lambda l: compressed_psum_leaf(l, axis), g)

    # every leaf fully replicated within the pod slice; sharded over axis
    spec = P()   # logical view: identical shapes per pod; axis is vmapped
    return _shard_map(body, mesh=mesh,
                      in_specs=(spec,), out_specs=spec,
                      check_vma=False)(grads)
