"""AdamW with decoupled weight decay, schedules, clipping — pure JAX.

Optimizer state lives in f32 regardless of parameter dtype (bf16-safe
training) and inherits the parameter sharding leaf-for-leaf, so the
optimizer adds no resharding traffic (the "MEM tile" of the Vespa plan —
its HBM bytes are charged to the mem island in the perf model).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # i32 scalar
    mu: Any                  # f32 pytree like params
    nu: Any                  # f32 pytree like params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - frac)
    else:
        decay = jnp.ones(())
    return cfg.lr * warm * decay


def init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(f32, params),
                      nu=jax.tree_util.tree_map(f32, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a), new_m.append(b), new_v.append(c)
    unf = partial(jax.tree_util.tree_unflatten, td)
    return (unf(new_p),
            AdamWState(step=step, mu=unf(new_m), nu=unf(new_v)),
            {"lr": lr, "grad_norm": gnorm})
