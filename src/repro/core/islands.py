"""Frequency islands (paper contribution C2) — design-time partition.

Every tile (and the NoC fabric itself) is assigned to an island; each island
carries an independent *rate* — the TPU adaptation of the paper's per-island
clock (DESIGN.md §C2).  Rates live on a discrete ladder mirroring the
paper's MHz steps (NoC: 10–100 MHz, tiles: 10–50 MHz, 5 MHz steps).

Resynchronizers: the paper inserts CDC resynchronizers at island
boundaries.  Here a boundary between islands that disagree on sharding
layout / replication K / precision implies a resharding (or dtype cast)
collective; :func:`resync_boundaries` enumerates them so core/noc.py can
charge their bytes and core/monitor.py can count their packets.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.tiles import TilePlan, TileSpec


@dataclass(frozen=True)
class RateLadder:
    """Discrete frequency ladder, verbatim from the paper's DFS actuators."""
    f_min_mhz: int = 10
    f_max_mhz: int = 50
    f_step_mhz: int = 5

    def levels_mhz(self) -> Tuple[int, ...]:
        return tuple(range(self.f_min_mhz, self.f_max_mhz + 1, self.f_step_mhz))

    def levels(self) -> Tuple[float, ...]:
        """Normalized rates f/f_max in (0, 1]."""
        return tuple(m / self.f_max_mhz for m in self.levels_mhz())

    def quantize(self, rate: float) -> float:
        lv = np.asarray(self.levels())
        return float(lv[int(np.argmin(np.abs(lv - rate)))])

    def voltages(self, tech) -> np.ndarray:
        """The coupled voltage ladder under a physical tech model: the
        absolute operating voltage (volts) at each frequency level, one
        step per step (:meth:`repro.core.voltage.TechModel.volt_of_freq`
        over :meth:`levels`)."""
        return tech.ladder_voltages(self)

    def legal_levels(self, tech) -> np.ndarray:
        """Mask of frequency levels inside the tech node's legal DVFS
        ratio range ``[L, U]`` — the steps a clamped commit can land on."""
        return tech.legal_levels(self)


# The paper's two ladders.
TILE_LADDER = RateLadder(10, 50, 5)
NOC_LADDER = RateLadder(10, 100, 5)


@dataclass(frozen=True)
class IslandSpec:
    name: str
    tiles: Tuple[str, ...]                   # tile names from the TilePlan
    ladder: RateLadder = TILE_LADDER
    rate: float = 1.0                        # normalized f/f_max
    fixed: bool = False                      # fixed clock (no DFS actuator)

    def with_rate(self, rate: float) -> "IslandSpec":
        assert not self.fixed, f"island {self.name} has a fixed clock"
        return replace(self, rate=self.ladder.quantize(rate))


@dataclass(frozen=True)
class IslandConfig:
    """A full island partition + rate assignment (one 'SoC configuration')."""
    islands: Tuple[IslandSpec, ...]
    version: int = 0

    def _tile_index(self) -> Dict[str, int]:
        """Memoized tile -> island-position map (the sim hot path calls
        :meth:`island_of` per tile per engine build; the old linear scan
        was O(#islands) per lookup).  The cache is per *instance*, so any
        rate/partition change — ``with_rates``/``replace`` always build a
        new frozen instance and bump ``version`` — starts from a fresh
        map; first-wins on (invalid) duplicate assignments, matching the
        scan."""
        m = self.__dict__.get("_tile_index_cache")
        if m is None:
            m = {}
            for i, isl in enumerate(self.islands):
                for t in isl.tiles:
                    m.setdefault(t, i)
            object.__setattr__(self, "_tile_index_cache", m)
        return m

    def island_of(self, tile_name: str) -> IslandSpec:
        return self.islands[self._tile_index()[tile_name]]

    def rate_of(self, tile_name: str) -> float:
        return self.island_of(tile_name).rate

    def with_rates(self, rates: Dict[str, float]) -> "IslandConfig":
        new = tuple(
            isl.with_rate(rates[isl.name]) if isl.name in rates else isl
            for isl in self.islands)
        return replace(self, islands=new, version=self.version + 1)

    def names(self) -> Tuple[str, ...]:
        return tuple(i.name for i in self.islands)

    def voltage_ladders(self, tech) -> Dict[str, np.ndarray]:
        """Per-island voltage ladders under a physical tech model:
        island name -> operating volts at each of its frequency levels
        (the V/f pairs a DFS commit selects between)."""
        return {i.name: i.ladder.voltages(tech) for i in self.islands}


def default_islands(plan: TilePlan) -> IslandConfig:
    """Paper-faithful island split: each accelerator tile its own island,
    NoC+MEM together (the paper's 10–100 MHz island), IO+host together."""
    islands: List[IslandSpec] = []
    acc = [t for t in plan.tiles if t.kind not in ("noc", "mem", "io")]
    for t in acc:
        islands.append(IslandSpec(t.name, (t.name,), TILE_LADDER, 1.0))
    islands.append(IslandSpec(
        "noc_mem",
        tuple(t.name for t in plan.tiles if t.kind in ("noc", "mem")),
        NOC_LADDER, 1.0))
    io = tuple(t.name for t in plan.tiles if t.kind == "io")
    if io:
        islands.append(IslandSpec("cpu_io", io, TILE_LADDER, 1.0, fixed=True))
    return IslandConfig(tuple(islands))


def validate_islands(cfg: IslandConfig, plan: TilePlan) -> None:
    """Every tile in exactly one island (a partition, as in the paper)."""
    seen: Dict[str, str] = {}
    for isl in cfg.islands:
        for t in isl.tiles:
            assert t not in seen, f"tile {t} in islands {seen[t]} and {isl.name}"
            seen[t] = isl.name
    for t in plan.tiles:
        assert t.name in seen, f"tile {t.name} not assigned to any island"


@dataclass(frozen=True)
class Boundary:
    """A resynchronizer site: directed tile-to-tile stream crossing islands
    (or crossing an MRA bridge / precision change within one island)."""
    src: str
    dst: str
    reason: str          # "island" | "mra" | "precision"


# Dataflow edges between tile kinds in a decoder LM (per layer, static).
_FLOW = [
    ("io", "embed"), ("embed", "attn"), ("embed", "ssm"),
    ("attn", "ffn"), ("attn", "moe"), ("ssm", "shared_attn"),
    ("shared_attn", "ssm"), ("ffn", "attn"), ("moe", "attn"),
    ("ssm", "embed"), ("ffn", "embed"), ("moe", "embed"),
    ("attn", "mem"), ("ffn", "mem"), ("moe", "mem"), ("ssm", "mem"),
]


def resync_boundaries(plan: TilePlan, islands: IslandConfig) -> List[Boundary]:
    kind_to_name = {}
    for t in plan.tiles:
        kind_to_name.setdefault(t.kind, t.name)
    out: List[Boundary] = []
    for src_k, dst_k in _FLOW:
        if src_k not in kind_to_name or dst_k not in kind_to_name:
            continue
        src, dst = kind_to_name[src_k], kind_to_name[dst_k]
        if islands.island_of(src).name != islands.island_of(dst).name:
            out.append(Boundary(src, dst, "island"))
        src_t, dst_t = plan.tile(src), plan.tile(dst)
        if src_t.replication != dst_t.replication:
            out.append(Boundary(src, dst, "mra"))
    return out
