"""Design-space exploration driver — what Vespa exists for.

Sweeps the paper's three design axes and reports Pareto-optimal points:

* replication K per accelerator tile    (C1),
* per-island rate assignment            (C2),
* tile placement on the NoC grid        (Fig. 2's A1-near vs A2-far).

Two evaluation backends: the analytic :class:`SoCPerfModel` (fast, used for
sweeps and the paper-claims benchmarks) and the dry-run roofline
(launch/dryrun.py), used to validate chosen points against compiled HLO.

Two evaluation *shapes*:

* :func:`sweep_soc` — the original scalar ``itertools.product`` loop.  It
  builds a :class:`DesignPoint` per point and is kept as the slow,
  obviously-correct reference the batched engine is tested against.
* :func:`grid_sweep` — the batched array program.  It materializes the
  full cross-product (joint multi-accelerator K ladders x island-rate
  ladders x all grid placements) as broadcast axes, pushes the whole grid
  through ``SoCPerfModel.accel_throughput_batch`` in one vectorized call,
  and returns a :class:`SweepResult` of flat objective arrays — millions
  of design points per second, no per-point Python objects.  DesignPoints
  are materialized lazily (:meth:`SweepResult.design_point`) only for the
  handful of survivors (Pareto front / top-k).

The Pareto front is sort-based O(N log N) (:func:`pareto_front_indices`);
the O(N^2) brute force survives as :func:`pareto_front_bruteforce` for
verification.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.islands import IslandConfig, NOC_LADDER, TILE_LADDER
from repro.core.noc import pos_index
from repro.core.perfmodel import AccelWorkload, SoCPerfModel, chip_power
from repro.core.replication import (replication_area_model,
                                    replication_throughput_model)
from repro.core.tiles import TilePlan


@dataclass(frozen=True)
class DesignPoint:
    replication: Dict[str, int]
    rates: Dict[str, float]
    placement: Dict[str, Tuple[int, int]]
    throughput: float
    area: float                    # normalized resource cost
    energy_per_unit: float

    def key(self):
        return (tuple(sorted(self.replication.items())),
                tuple(sorted(self.rates.items())),
                tuple(sorted(self.placement.items())))


# ---------------------------------------------------------------------------
# Pareto fronts
# ---------------------------------------------------------------------------


def pareto_front_indices(throughput, area, energy) -> np.ndarray:
    """Indices of the 3-objective Pareto front in O(N log N).

    Maximize ``throughput``; minimize ``area`` and ``energy``.  Points are
    processed in descending-throughput groups; a (area, energy) staircase
    of the already-accepted, strictly-faster points answers "is this point
    dominated?" in O(log F).  Semantics match the O(N^2) brute force: q
    dominates p iff q is >=/<=/<= on all three objectives and strictly
    better on at least one (exact duplicates do not dominate each other).
    Returns indices in ascending input order.
    """
    thr = np.asarray(throughput, dtype=np.float64)
    area = np.asarray(area, dtype=np.float64)
    energy = np.asarray(energy, dtype=np.float64)
    n = thr.shape[0]
    if n == 0:
        return np.empty(0, dtype=np.int64)
    order = np.lexsort((energy, area, -thr))
    # python lists: ~3x faster to index in the scan than numpy scalars
    thr_l = thr[order].tolist()
    area_l = area[order].tolist()
    energy_l = energy[order].tolist()
    order_l = order.tolist()

    keep: List[int] = []
    stair_a: List[float] = []       # staircase areas, ascending
    stair_e: List[float] = []       # matching energies, strictly descending
    INF = float("inf")
    i = 0
    while i < n:
        j = i
        t = thr_l[i]
        while j < n and thr_l[j] == t:
            j += 1
        # 1) cull against strictly-faster accepted points
        survivors = []
        for p in range(i, j):
            a, e = area_l[p], energy_l[p]
            s = bisect_right(stair_a, a)
            if s > 0 and stair_e[s - 1] <= e:
                continue                      # dominated by a faster point
            survivors.append(p)
        # 2) within-group dominance (equal throughput; needs strictness).
        # survivors are sorted by (area, energy) thanks to the lexsort.
        best_e_smaller_area = INF             # min energy over area < cur
        cur_area, cur_min_e = None, INF       # min energy within area == cur
        kept_group: List[Tuple[float, float]] = []
        for p in survivors:
            a, e = area_l[p], energy_l[p]
            if a != cur_area:
                best_e_smaller_area = min(best_e_smaller_area, cur_min_e)
                cur_area, cur_min_e = a, INF
            if not (best_e_smaller_area <= e or cur_min_e < e):
                keep.append(order_l[p])
                kept_group.append((a, e))
            cur_min_e = min(cur_min_e, e)
        # 3) fold the group's minimal (area, energy) pairs into the staircase
        for a, e in kept_group:
            s = bisect_right(stair_a, a)
            if s > 0 and stair_e[s - 1] <= e:
                continue                      # already covered
            stair_a.insert(s, a)
            stair_e.insert(s, e)
            k = s + 1
            while k < len(stair_a) and stair_e[k] >= e:
                k += 1
            del stair_a[s + 1:k]
            del stair_e[s + 1:k]
        i = j
    keep.sort()
    return np.asarray(keep, dtype=np.int64)


def pareto_front_bruteforce(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """O(N^2) reference implementation (kept for verification/tests)."""
    front: List[DesignPoint] = []
    for p in points:
        dominated = False
        for q in points:
            if q is p:
                continue
            if (q.throughput >= p.throughput and q.area <= p.area
                    and q.energy_per_unit <= p.energy_per_unit
                    and (q.throughput > p.throughput or q.area < p.area
                         or q.energy_per_unit < p.energy_per_unit)):
                dominated = True
                break
        if not dominated:
            front.append(p)
    return front


def pareto_front(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Maximize throughput, minimize area & energy — O(N log N)."""
    pts = list(points)
    idx = pareto_front_indices(
        np.asarray([p.throughput for p in pts]),
        np.asarray([p.area for p in pts]),
        np.asarray([p.energy_per_unit for p in pts]))
    return [pts[i] for i in idx]


# ---------------------------------------------------------------------------
# Batched grid sweep
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class SweepResult:
    """Objective arrays for a full cross-product sweep, plus lazy
    :class:`DesignPoint` materialization.

    ``axes`` is the ordered list of (name, values) swept dimensions; flat
    arrays are C-ordered over ``shape``, so axis values for point ``i`` are
    recovered with ``np.unravel_index`` — no per-point objects exist until
    :meth:`design_point` is called for a survivor.
    """
    axes: Tuple[Tuple[str, Tuple], ...]
    shape: Tuple[int, ...]
    workloads: Tuple[AccelWorkload, ...]
    n_tg: int
    throughput: np.ndarray              # (N,) float64, total across accels
    area: np.ndarray                    # (N,) float64
    energy_per_unit: np.ndarray         # (N,) float64
    valid: np.ndarray                   # (N,) bool (placement collisions out)
    mem_traffic: Optional[np.ndarray] = None   # (N,) float64, Fig.-4 model
    elapsed_s: float = 0.0
    backend: str = "numpy"

    def __len__(self) -> int:
        return int(self.throughput.shape[0])

    @property
    def n_valid(self) -> int:
        return int(self.valid.sum())

    @property
    def points_per_second(self) -> float:
        return len(self) / self.elapsed_s if self.elapsed_s > 0 else float("inf")

    def pareto_indices(self) -> np.ndarray:
        """Flat indices of the (valid-only) Pareto front, O(N log N)."""
        flat = np.nonzero(self.valid)[0]
        sub = pareto_front_indices(self.throughput[flat], self.area[flat],
                                   self.energy_per_unit[flat])
        return flat[sub]

    def topk_indices(self, k: int, objective: str = "throughput",
                     maximize: Optional[bool] = None) -> np.ndarray:
        """Flat indices of the k best valid points on one objective,
        best-first, via argpartition (no full sort, no DesignPoints)."""
        vals = getattr(self, objective)
        if maximize is None:
            maximize = objective == "throughput"
        flat = np.nonzero(self.valid)[0]
        v = vals[flat]
        k = min(k, v.shape[0])
        if k == 0:
            return np.empty(0, dtype=np.int64)
        key = -v if maximize else v
        part = np.argpartition(key, k - 1)[:k]
        return flat[part[np.argsort(key[part], kind="stable")]]

    def axis_values(self, i: int) -> Dict[str, object]:
        """Swept axis values of flat point ``i`` as {axis_name: value}."""
        coords = np.unravel_index(i, self.shape)
        return {name: values[c]
                for (name, values), c in zip(self.axes, coords)}

    def design_point(self, i: int) -> DesignPoint:
        """Materialize one flat index as a :class:`DesignPoint`."""
        av = self.axis_values(i)
        replication = {wl.name: int(av[f"K:{wl.name}"])
                       for wl in self.workloads}
        placement = {wl.name: tuple(av[f"pos:{wl.name}"])
                     for wl in self.workloads}
        rates = {"acc": float(av["f_acc"]), "noc_mem": float(av["f_noc"]),
                 "tg": float(av["f_tg"])}
        return DesignPoint(
            replication=replication, rates=rates, placement=placement,
            throughput=float(self.throughput[i]), area=float(self.area[i]),
            energy_per_unit=float(self.energy_per_unit[i]))

    def design_points(self, indices: Iterable[int]) -> List[DesignPoint]:
        return [self.design_point(int(i)) for i in indices]


def _axis(values, dim: int, ndim: int) -> np.ndarray:
    """Reshape a 1-D axis to broadcast at dimension ``dim`` of ``ndim``."""
    a = np.asarray(values)
    shape = [1] * ndim
    shape[dim] = a.shape[0]
    return a.reshape(shape)


def grid_sweep(model: SoCPerfModel,
               workloads,
               *,
               ks: Sequence[int] = (1, 2, 4),
               acc_rates: Sequence[float] = (0.2, 0.6, 1.0),
               noc_rates: Sequence[float] = (0.1, 0.5, 1.0),
               tg_rates: Sequence[float] = (1.0,),
               positions: Optional[Sequence[Tuple[int, int]]] = None,
               n_tg: int = 0,
               backend: str = "numpy") -> SweepResult:
    """Batched cross-product sweep over the paper's design axes.

    ``workloads`` is one :class:`AccelWorkload` or a sequence for a *joint*
    multi-accelerator sweep (each accelerator gets its own K axis and its
    own placement axis; rates are shared, as in the paper's islands).  The
    swept dimensions, in axis order, are::

        K:<wl> (per accel) | f_noc | f_acc | f_tg | pos:<wl> (per accel)

    ``positions`` defaults to every grid node except the MEM tile.  Joint
    placements where two accelerators collide are masked invalid (their
    objective entries are still computed — the arrays stay rectangular —
    but :meth:`SweepResult.pareto_indices` / ``topk_indices`` skip them).

    Throughput of a joint point is the sum of the accelerators' modeled
    throughputs; area sums each accelerator's replication cost; energy is
    chip power at (f_acc, f_noc) per unit of total throughput — identical
    formulas to :func:`sweep_soc`, evaluated as arrays.  With
    ``backend="jax"`` the throughput kernel runs jit-compiled.
    """
    if isinstance(workloads, AccelWorkload):
        workloads = (workloads,)
    workloads = tuple(workloads)
    if positions is None:
        positions = [(r, c) for r in range(model.noc.rows)
                     for c in range(model.noc.cols)
                     if (r, c) != model.mem_pos]
    positions = [tuple(p) for p in positions]
    pos_idx = np.asarray([pos_index(model.noc, p) for p in positions])

    A = len(workloads)
    axes: List[Tuple[str, Tuple]] = []
    for wl in workloads:
        axes.append((f"K:{wl.name}", tuple(int(k) for k in ks)))
    axes.append(("f_noc", tuple(float(f) for f in noc_rates)))
    axes.append(("f_acc", tuple(float(f) for f in acc_rates)))
    axes.append(("f_tg", tuple(float(f) for f in tg_rates)))
    for wl in workloads:
        axes.append((f"pos:{wl.name}", tuple(positions)))
    ndim = len(axes)
    shape = tuple(len(v) for _, v in axes)

    t0 = time.perf_counter()
    k_ax = [_axis([float(k) for k in ks], a, ndim) for a in range(A)]
    fn_ax = _axis(list(noc_rates), A, ndim)
    fa_ax = _axis(list(acc_rates), A + 1, ndim)
    ft_ax = _axis(list(tg_rates), A + 2, ndim)
    pos_ax = [_axis(pos_idx, A + 3 + a, ndim) for a in range(A)]

    total_thr = np.zeros(shape, dtype=np.float64)
    for a, wl in enumerate(workloads):
        thr = model.accel_throughput_batch(
            base_mbps=wl.base_mbps, wire_share=wl.wire_share, k=k_ax[a],
            f_acc=fa_ax, f_noc=fn_ax, f_tg=ft_ax, n_tg=n_tg,
            pos_idx=pos_ax[a], backend=backend)
        total_thr = total_thr + np.broadcast_to(thr, shape)

    # area: replication cost per accel, looked up per K level
    area_by_k = {int(k): replication_area_model(
        weight_bytes=1.0, act_bytes=0.5, k=int(k))["total_bytes_per_dev"]
        for k in ks}
    area = np.zeros(shape, dtype=np.float64)
    for a in range(A):
        area = area + _axis([area_by_k[int(k)] for k in ks], a, ndim)

    power = chip_power(fa_ax, busy=1.0) + 0.3 * chip_power(fn_ax, busy=1.0)
    energy = np.broadcast_to(power, shape) / np.maximum(total_thr, 1e-9)

    # Fig.-4 memory-pressure objective: offered MEM traffic at each rate
    # point (placement-independent, so it broadcasts over the K/pos axes)
    mem_traffic = np.broadcast_to(
        model.memory_traffic_batch(f_acc=fa_ax, f_noc=fn_ax, f_tg=ft_ax,
                                   n_tg=n_tg, n_accels=A), shape)

    valid = np.ones(shape, dtype=bool)
    for a in range(A):
        for b in range(a + 1, A):
            valid &= pos_ax[a] != pos_ax[b]

    elapsed = time.perf_counter() - t0
    return SweepResult(
        axes=tuple(axes), shape=shape, workloads=workloads, n_tg=n_tg,
        throughput=total_thr.ravel(),
        area=np.ascontiguousarray(np.broadcast_to(area, shape)).ravel(),
        energy_per_unit=energy.ravel(), valid=valid.ravel(),
        mem_traffic=np.ascontiguousarray(mem_traffic).ravel(),
        elapsed_s=elapsed, backend=backend)


# ---------------------------------------------------------------------------
# Closed-loop re-ranking: the static sweep meets the runtime simulator
# ---------------------------------------------------------------------------


@dataclass
class ClosedLoopScore:
    """Simulated runtime scores for a set of sweep survivors.

    ``indices`` are flat :class:`SweepResult` indices; the parallel arrays
    hold each point's simulated p99 latency, energy per request and
    sustained throughput under the replayed trace.  ``order`` re-ranks
    ``indices`` best-first: points meeting the p99 SLA sorted by energy
    per request, then SLA violators by how badly they miss it.

    ``results`` holds per-point ``sim.SimResult`` objects on the
    sequential path; on the batched path it holds the single
    ``sim.BatchSimResult`` of the one stacked replay.
    """
    indices: np.ndarray                 # (M,) int64
    p99_latency_s: np.ndarray           # (M,) float64
    energy_per_request_j: np.ndarray    # (M,) float64
    throughput_rps: np.ndarray          # (M,) float64
    order: np.ndarray                   # (M,) int64 positions into indices
    results: List[object]               # SimResults, or one BatchSimResult

    def ranked_indices(self) -> np.ndarray:
        """Flat SweepResult indices, best-first."""
        return self.indices[self.order]


def _rank_scores(p99: np.ndarray, ept: np.ndarray,
                 p99_sla_s: Optional[float]) -> np.ndarray:
    if p99_sla_s is not None:
        miss = np.maximum(0.0, p99 / p99_sla_s - 1.0)
        return np.lexsort((ept, miss))      # SLA first, then energy
    return np.lexsort((p99, ept))           # energy first, p99 tie-break


def closed_loop_score(result: SweepResult, trace, *,
                      model: SoCPerfModel,
                      indices: Optional[Sequence[int]] = None,
                      top: int = 8,
                      p99_sla_s: Optional[float] = None,
                      controller_factory=None,
                      batch_controller_factory=None,
                      req_mb: float = 0.1,
                      sim_config=None,
                      batch: Optional[bool] = None,
                      backend: str = "numpy",
                      trace_seed: int = 0) -> ClosedLoopScore:
    """Re-rank static-sweep survivors by *simulated* runtime behaviour.

    The static objectives of :func:`grid_sweep` assume steady saturated
    streams; under dynamic traffic two points with equal static throughput
    can have wildly different tail latency and idle-power profiles.  This
    bridge replays ``trace`` (a ``repro.sim.Trace`` whose destinations map
    1:1 to ``result.workloads``) through each survivor — by default the
    ``top`` throughput points of the Pareto front — with an optional
    online DFS controller in the loop, and ranks by (p99 SLA met, energy
    per request).  The static sweep and the runtime loop become one
    pipeline::

        res   = grid_sweep(model, wls, ...)
        score = closed_loop_score(res, diurnal_trace(...), model=model,
                                  p99_sla_s=0.05)
        best  = res.design_point(int(score.ranked_indices()[0]))

    **Batched by default**: the survivors are stacked into one
    ``repro.sim.BatchSimPlatform`` and replayed as a single array program
    (``backend="numpy"`` or ``"jax"`` for the ``lax.scan`` tick loop) —
    re-ranking ~1k survivors is one batched run, not ~1k sequential sims.
    ``batch_controller_factory`` receives the stacked platform and must
    return a ``repro.sim.BatchControllerHarness`` (or None).  Passing the
    legacy per-point ``controller_factory`` (a
    ``repro.sim.ControllerHarness`` per materialized ``SimPlatform``)
    selects the sequential path, as does ``batch=False``; the sequential
    path is the differential-test reference and produces identical
    rankings (tested).

    Determinism: ``trace`` may be a callable ``trace(seed) -> Trace``; it
    is invoked with the explicit ``trace_seed``, so repeated scoring of
    the same survivors replays an identical trace instead of relying on
    whatever generator state the caller happened to have.  Imports
    ``repro.sim`` lazily — the core DSE layer stays importable without
    the simulation subsystem.
    """
    from repro.sim import SimConfig, SimEngine, SimPlatform

    if callable(trace):
        trace = trace(trace_seed)

    if indices is None:
        pf = result.pareto_indices()
        ordr = np.argsort(-result.throughput[pf], kind="stable")
        indices = pf[ordr][:top]
    indices = np.asarray(indices, dtype=np.int64)

    if batch is None:
        batch = controller_factory is None
    assert not (batch and controller_factory is not None), \
        "per-point controller_factory requires batch=False"

    if batch:
        from repro.sim import BatchSimEngine, BatchSimPlatform
        platform = BatchSimPlatform.from_design_points(
            model, result, indices, req_mb=req_mb, n_tg=result.n_tg)
        controller = (batch_controller_factory(platform)
                      if batch_controller_factory is not None else None)
        engine = BatchSimEngine(platform, config=sim_config or SimConfig(),
                                controller=controller, backend=backend)
        r = engine.run(trace)
        p99 = r.p99_latency_s
        ept = r.energy_per_request_j
        thr = r.throughput_rps
        results: List[object] = [r]
    else:
        p99 = np.empty(indices.shape[0])
        ept = np.empty(indices.shape[0])
        thr = np.empty(indices.shape[0])
        results = []
        for j, i in enumerate(indices):
            dp = result.design_point(int(i))
            platform = SimPlatform.from_design_point(
                model, dp, result.workloads, req_mb=req_mb, n_tg=result.n_tg)
            controller = (controller_factory(platform)
                          if controller_factory is not None else None)
            engine = SimEngine(platform,
                               config=sim_config or SimConfig(),
                               controller=controller)
            r = engine.run(trace)
            results.append(r)
            p99[j] = r.p99_latency_s
            ept[j] = r.energy_per_request_j
            thr[j] = r.throughput_rps

    order = _rank_scores(p99, ept, p99_sla_s)
    return ClosedLoopScore(indices=indices, p99_latency_s=p99,
                           energy_per_request_j=ept, throughput_rps=thr,
                           order=np.asarray(order, dtype=np.int64),
                           results=results)


# ---------------------------------------------------------------------------
# Scalar reference sweep (original API)
# ---------------------------------------------------------------------------


def sweep_soc(model: SoCPerfModel, wl: AccelWorkload,
              *, ks: Sequence[int] = (1, 2, 4),
              noc_rates: Sequence[float] = (0.1, 0.5, 1.0),
              acc_rates: Sequence[float] = (0.2, 0.6, 1.0),
              positions: Sequence[Tuple[int, int]] = ((1, 1), (3, 3)),
              n_tg: int = 0) -> List[DesignPoint]:
    """Exhaustive scalar sweep over the paper's axes for one accelerator.

    The per-point reference path; :func:`grid_sweep` is the batched
    equivalent and is tested to match it within fp tolerance."""
    out: List[DesignPoint] = []
    for k, fn, fa, pos in itertools.product(ks, noc_rates, acc_rates,
                                            positions):
        w = dataclasses.replace(wl, replication=k)
        rates = {"acc": fa, "noc_mem": fn, "tg": 1.0}
        thr = model.accel_throughput(w, pos, rates, n_tg)
        area = replication_area_model(
            weight_bytes=1.0, act_bytes=0.5, k=k)["total_bytes_per_dev"]
        power = chip_power(fa, busy=1.0) + 0.3 * chip_power(fn, busy=1.0)
        out.append(DesignPoint(
            replication={wl.name: k}, rates=rates,
            placement={wl.name: pos}, throughput=thr, area=area,
            energy_per_unit=power / max(thr, 1e-9)))
    return out


def sweep_replication_roofline(eval_cell: Callable[[int], Dict[str, float]],
                               ks: Sequence[int] = (1, 2, 4, 8)
                               ) -> List[Dict[str, float]]:
    """Pod-scale MRA sweep: ``eval_cell(K)`` lowers/compiles the cell on the
    K-factored mesh and returns roofline terms; used by §Perf hillclimbs."""
    rows = []
    for k in ks:
        r = dict(eval_cell(k))
        r["K"] = k
        r["predicted_gain"] = replication_throughput_model(k)
        rows.append(r)
    return rows


def summarize(points: Sequence[DesignPoint], top: int = 10) -> str:
    front = pareto_front(points)
    front.sort(key=lambda p: -p.throughput)
    lines = [f"{len(points)} points, {len(front)} on Pareto front"]
    for p in front[:top]:
        lines.append(
            f"  K={p.replication}  rates={ {k: round(v, 2) for k, v in p.rates.items()} }"
            f"  pos={p.placement}  thr={p.throughput:.2f}  area={p.area:.2f}"
            f"  E/u={p.energy_per_unit:.1f}")
    return "\n".join(lines)


def summarize_result(res: SweepResult, top: int = 10) -> str:
    """Summary of a batched sweep without materializing all points."""
    front_idx = res.pareto_indices()
    order = np.argsort(-res.throughput[front_idx], kind="stable")
    lines = [f"{len(res)} points ({res.n_valid} valid, "
             f"{res.points_per_second:,.0f} pts/s), "
             f"{front_idx.shape[0]} on Pareto front"]
    for p in res.design_points(front_idx[order][:top]):
        lines.append(
            f"  K={p.replication}  rates={ {k: round(v, 2) for k, v in p.rates.items()} }"
            f"  pos={p.placement}  thr={p.throughput:.2f}  area={p.area:.2f}"
            f"  E/u={p.energy_per_unit:.1f}")
    return "\n".join(lines)
