"""Design-space exploration driver — what Vespa exists for.

Sweeps the paper's three design axes and reports Pareto-optimal points:

* replication K per accelerator tile    (C1),
* per-island rate assignment            (C2),
* tile placement on the NoC grid        (Fig. 2's A1-near vs A2-far).

Two evaluation backends: the analytic :class:`SoCPerfModel` (fast, used for
sweeps and the paper-claims benchmarks) and the dry-run roofline
(launch/dryrun.py), used to validate chosen points against compiled HLO.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.islands import IslandConfig, NOC_LADDER, TILE_LADDER
from repro.core.perfmodel import AccelWorkload, SoCPerfModel, chip_power
from repro.core.replication import (replication_area_model,
                                    replication_throughput_model)
from repro.core.tiles import TilePlan


@dataclass(frozen=True)
class DesignPoint:
    replication: Dict[str, int]
    rates: Dict[str, float]
    placement: Dict[str, Tuple[int, int]]
    throughput: float
    area: float                    # normalized resource cost
    energy_per_unit: float

    def key(self):
        return (tuple(sorted(self.replication.items())),
                tuple(sorted(self.rates.items())),
                tuple(sorted(self.placement.items())))


def pareto_front(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Maximize throughput, minimize area & energy."""
    front: List[DesignPoint] = []
    for p in points:
        dominated = False
        for q in points:
            if q is p:
                continue
            if (q.throughput >= p.throughput and q.area <= p.area
                    and q.energy_per_unit <= p.energy_per_unit
                    and (q.throughput > p.throughput or q.area < p.area
                         or q.energy_per_unit < p.energy_per_unit)):
                dominated = True
                break
        if not dominated:
            front.append(p)
    return front


def sweep_soc(model: SoCPerfModel, wl: AccelWorkload,
              *, ks: Sequence[int] = (1, 2, 4),
              noc_rates: Sequence[float] = (0.1, 0.5, 1.0),
              acc_rates: Sequence[float] = (0.2, 0.6, 1.0),
              positions: Sequence[Tuple[int, int]] = ((1, 1), (3, 3)),
              n_tg: int = 0) -> List[DesignPoint]:
    """Exhaustive sweep over the paper's axes for one accelerator."""
    out: List[DesignPoint] = []
    for k, fn, fa, pos in itertools.product(ks, noc_rates, acc_rates,
                                            positions):
        w = dataclasses.replace(wl, replication=k)
        rates = {"acc": fa, "noc_mem": fn, "tg": 1.0}
        thr = model.accel_throughput(w, pos, rates, n_tg)
        area = replication_area_model(
            weight_bytes=1.0, act_bytes=0.5, k=k)["total_bytes_per_dev"]
        power = chip_power(fa, busy=1.0) + 0.3 * chip_power(fn, busy=1.0)
        out.append(DesignPoint(
            replication={wl.name: k}, rates=rates,
            placement={wl.name: pos}, throughput=thr, area=area,
            energy_per_unit=power / max(thr, 1e-9)))
    return out


def sweep_replication_roofline(eval_cell: Callable[[int], Dict[str, float]],
                               ks: Sequence[int] = (1, 2, 4, 8)
                               ) -> List[Dict[str, float]]:
    """Pod-scale MRA sweep: ``eval_cell(K)`` lowers/compiles the cell on the
    K-factored mesh and returns roofline terms; used by §Perf hillclimbs."""
    rows = []
    for k in ks:
        r = dict(eval_cell(k))
        r["K"] = k
        r["predicted_gain"] = replication_throughput_model(k)
        rows.append(r)
    return rows


def summarize(points: Sequence[DesignPoint], top: int = 10) -> str:
    front = pareto_front(points)
    front.sort(key=lambda p: -p.throughput)
    lines = [f"{len(points)} points, {len(front)} on Pareto front"]
    for p in front[:top]:
        lines.append(
            f"  K={p.replication}  rates={ {k: round(v, 2) for k, v in p.rates.items()} }"
            f"  pos={p.placement}  thr={p.throughput:.2f}  area={p.area:.2f}"
            f"  E/u={p.energy_per_unit:.1f}")
    return "\n".join(lines)
