"""Design-space exploration driver — what Vespa exists for.

Sweeps the paper's three design axes and reports Pareto-optimal points:

* replication K per accelerator tile    (C1),
* per-island rate assignment            (C2),
* tile placement on the NoC grid        (Fig. 2's A1-near vs A2-far).

Two evaluation backends: the analytic :class:`SoCPerfModel` (fast, used for
sweeps and the paper-claims benchmarks) and the dry-run roofline
(launch/dryrun.py), used to validate chosen points against compiled HLO.

Two evaluation *shapes*:

* :func:`sweep_soc` — the original scalar ``itertools.product`` loop.  It
  builds a :class:`DesignPoint` per point and is kept as the slow,
  obviously-correct reference the batched engine is tested against.
* :func:`grid_sweep` — the batched array program.  It materializes the
  full cross-product (joint multi-accelerator K ladders x island-rate
  ladders x all grid placements) as broadcast axes, pushes the whole grid
  through ``SoCPerfModel.accel_throughput_batch`` in one vectorized call,
  and returns a :class:`SweepResult` of flat objective arrays — millions
  of design points per second, no per-point Python objects.  DesignPoints
  are materialized lazily (:meth:`SweepResult.design_point`) only for the
  handful of survivors (Pareto front / top-k).

The Pareto front is sort-based O(N log N) (:func:`pareto_front_indices`);
the O(N^2) brute force survives as :func:`pareto_front_bruteforce` for
verification.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from bisect import bisect_right
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.islands import IslandConfig, NOC_LADDER, TILE_LADDER
from repro.core.noc import pos_index
from repro.core.perfmodel import (AccelWorkload, NOC_POWER_SHARE,
                                  SoCPerfModel, chip_power,
                                  chip_power_coeffs,
                                  _memory_traffic_math_per_accel,
                                  _throughput_math)
from repro.core.replication import (replication_area_model,
                                    replication_throughput_model)
from repro.core.tiles import TilePlan
from repro.core.voltage import TechModel, tech_axis_coeffs


@dataclass(frozen=True)
class DesignPoint:
    replication: Dict[str, int]
    rates: Dict[str, float]
    placement: Dict[str, Tuple[int, int]]
    throughput: float
    area: float                    # normalized resource cost
    energy_per_unit: float
    tech: Optional[Tuple[int, str]] = None   # (node, variant) when swept

    def key(self):
        return (tuple(sorted(self.replication.items())),
                tuple(sorted(self.rates.items())),
                tuple(sorted(self.placement.items())),
                self.tech)


# ---------------------------------------------------------------------------
# Pareto fronts
# ---------------------------------------------------------------------------


def pareto_front_indices(throughput, area, energy) -> np.ndarray:
    """Indices of the 3-objective Pareto front in O(N log N).

    Maximize ``throughput``; minimize ``area`` and ``energy``.  Points are
    processed in descending-throughput groups; a (area, energy) staircase
    of the already-accepted, strictly-faster points answers "is this point
    dominated?" in O(log F).  Semantics match the O(N^2) brute force: q
    dominates p iff q is >=/<=/<= on all three objectives and strictly
    better on at least one (exact duplicates do not dominate each other).
    Returns indices in ascending input order.
    """
    thr = np.asarray(throughput, dtype=np.float64)
    area = np.asarray(area, dtype=np.float64)
    energy = np.asarray(energy, dtype=np.float64)
    n = thr.shape[0]
    if n == 0:
        return np.empty(0, dtype=np.int64)
    order = np.lexsort((energy, area, -thr))
    # python lists: ~3x faster to index in the scan than numpy scalars
    thr_l = thr[order].tolist()
    area_l = area[order].tolist()
    energy_l = energy[order].tolist()
    order_l = order.tolist()

    keep: List[int] = []
    stair_a: List[float] = []       # staircase areas, ascending
    stair_e: List[float] = []       # matching energies, strictly descending
    INF = float("inf")
    i = 0
    while i < n:
        j = i
        t = thr_l[i]
        while j < n and thr_l[j] == t:
            j += 1
        # 1) cull against strictly-faster accepted points
        survivors = []
        for p in range(i, j):
            a, e = area_l[p], energy_l[p]
            s = bisect_right(stair_a, a)
            if s > 0 and stair_e[s - 1] <= e:
                continue                      # dominated by a faster point
            survivors.append(p)
        # 2) within-group dominance (equal throughput; needs strictness).
        # survivors are sorted by (area, energy) thanks to the lexsort.
        best_e_smaller_area = INF             # min energy over area < cur
        cur_area, cur_min_e = None, INF       # min energy within area == cur
        kept_group: List[Tuple[float, float]] = []
        for p in survivors:
            a, e = area_l[p], energy_l[p]
            if a != cur_area:
                best_e_smaller_area = min(best_e_smaller_area, cur_min_e)
                cur_area, cur_min_e = a, INF
            if not (best_e_smaller_area <= e or cur_min_e < e):
                keep.append(order_l[p])
                kept_group.append((a, e))
            cur_min_e = min(cur_min_e, e)
        # 3) fold the group's minimal (area, energy) pairs into the staircase
        for a, e in kept_group:
            s = bisect_right(stair_a, a)
            if s > 0 and stair_e[s - 1] <= e:
                continue                      # already covered
            stair_a.insert(s, a)
            stair_e.insert(s, e)
            k = s + 1
            while k < len(stair_a) and stair_e[k] >= e:
                k += 1
            del stair_a[s + 1:k]
            del stair_e[s + 1:k]
        i = j
    keep.sort()
    return np.asarray(keep, dtype=np.int64)


def pareto_front_bruteforce(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """O(N^2) reference implementation (kept for verification/tests)."""
    front: List[DesignPoint] = []
    for p in points:
        dominated = False
        for q in points:
            if q is p:
                continue
            if (q.throughput >= p.throughput and q.area <= p.area
                    and q.energy_per_unit <= p.energy_per_unit
                    and (q.throughput > p.throughput or q.area < p.area
                         or q.energy_per_unit < p.energy_per_unit)):
                dominated = True
                break
        if not dominated:
            front.append(p)
    return front


def pareto_front(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Maximize throughput, minimize area & energy — O(N log N)."""
    pts = list(points)
    idx = pareto_front_indices(
        np.asarray([p.throughput for p in pts]),
        np.asarray([p.area for p in pts]),
        np.asarray([p.energy_per_unit for p in pts]))
    return [pts[i] for i in idx]


# ---------------------------------------------------------------------------
# Batched grid sweep
# ---------------------------------------------------------------------------


class _SweepIndexing:
    """Index machinery shared by the one-shot and chunked sweep results.

    Both carry the ordered ``axes`` (name, values) and the grid ``shape``;
    flat point indices are C-ordered over ``shape``, so any flat index —
    whether its objectives are stored densely (:class:`SweepResult`) or
    only for tracked survivors (:class:`ChunkedSweepResult`) — maps back
    to concrete axis values, per-island rate vectors and
    :class:`DesignPoint` objects the same way.  Subclasses provide
    ``axes``/``shape``/``workloads``/``n_tg`` plus
    :meth:`objective_values`.
    """

    @property
    def independent_islands(self) -> bool:
        """True when each accelerator island swept its own rate axis."""
        return all(name != "f_acc" for name, _ in self.axes)

    def axis_values(self, i: int) -> Dict[str, object]:
        """Swept axis values of flat point ``i`` as {axis_name: value}."""
        coords = np.unravel_index(i, self.shape)
        return {name: values[c]
                for (name, values), c in zip(self.axes, coords)}

    def _accel_rate(self, av: Dict[str, object], wl_name: str) -> float:
        key = f"f_acc:{wl_name}"
        return float(av[key] if key in av else av["f_acc"])

    def island_rates(self, i: int) -> Dict[str, float]:
        """Per-island rate vector of flat point ``i``: one entry per
        accelerator island (keyed by workload/tile name, the island naming
        ``repro.sim.SimPlatform.build`` uses) plus the shared ``noc_mem``
        island.  In shared mode every accelerator entry is the one swept
        ``f_acc``; the TG rate is an axis value (``axis_values``), not an
        island."""
        av = self.axis_values(i)
        out = {wl.name: self._accel_rate(av, wl.name)
               for wl in self.workloads}
        out["noc_mem"] = float(av["f_noc"])
        return out

    def design_point(self, i: int) -> DesignPoint:
        """Materialize one flat index as a :class:`DesignPoint`."""
        av = self.axis_values(i)
        replication = {wl.name: int(av[f"K:{wl.name}"])
                       for wl in self.workloads}
        placement = {wl.name: tuple(av[f"pos:{wl.name}"])
                     for wl in self.workloads}
        if self.independent_islands:
            rates = {wl.name: self._accel_rate(av, wl.name)
                     for wl in self.workloads}
        else:
            rates = {"acc": float(av["f_acc"])}
        rates["noc_mem"] = float(av["f_noc"])
        rates["tg"] = float(av["f_tg"])
        thr, area, energy = self._point_objectives(i)
        tech = av.get("tech")
        return DesignPoint(
            replication=replication, rates=rates, placement=placement,
            throughput=thr, area=area, energy_per_unit=energy,
            tech=None if tech is None else (int(tech[0]), str(tech[1])))

    def _point_objectives(self, i: int) -> Tuple[float, float, float]:
        return tuple(
            float(self.objective_values(name, np.asarray([i]))[0])
            for name in ("throughput", "area", "energy_per_unit"))

    def design_points(self, indices: Iterable[int]) -> List[DesignPoint]:
        return [self.design_point(int(i)) for i in indices]

    def design_arrays(self, indices) -> Dict[str, np.ndarray]:
        """Vectorized design decode for B flat indices — the batched-sim
        bridge (``repro.sim.BatchSimPlatform.from_design_points``).

        Returns ``k`` (B, A) float64 replication, ``pos`` (B, A, 2) int64
        grid coordinates, ``rates`` (B, A+1) float64 per-island rates in
        ``[*workload names, "noc_mem"]`` order, and ``f_tg`` (B,) float64
        — exactly the floats :meth:`design_point` would produce, without
        materializing B DesignPoints.
        """
        idx = np.asarray(indices, dtype=np.int64)
        coords = dict(zip((n for n, _ in self.axes),
                          np.unravel_index(idx, self.shape)))
        vals = {n: np.asarray(v) for n, v in self.axes}

        def axis(name):
            return vals[name][coords[name]]

        k = np.stack([axis(f"K:{wl.name}").astype(np.float64)
                      for wl in self.workloads], axis=-1)
        pos = np.stack([axis(f"pos:{wl.name}") for wl in self.workloads],
                       axis=-2).astype(np.int64)
        fa_cols = [axis(f"f_acc:{wl.name}"
                        if self.independent_islands else "f_acc")
                   for wl in self.workloads]
        rates = np.stack(fa_cols + [axis("f_noc")], axis=-1).astype(
            np.float64)
        return {"k": k, "pos": pos, "rates": rates,
                "f_tg": axis("f_tg").astype(np.float64)}


# Objectives tracked by the chunked streaming sweep: name -> maximize?
_TRACKED_OBJECTIVES = (("throughput", True), ("area", False),
                       ("energy_per_unit", False), ("mem_traffic", False))


def _topk_select(key: np.ndarray, indices: np.ndarray, k: int) -> np.ndarray:
    """Positions of the k smallest ``key`` entries, ordered — and, at the
    k-th-value boundary, *selected* — by (key, global index).

    argpartition alone picks arbitrarily among boundary ties, which would
    make one-shot and chunked sweeps disagree on tie-heavy objectives
    (area has a handful of distinct values); widening the partition to
    every entry tied with the k-th value and resolving by flat index makes
    the selection deterministic and chunking-invariant."""
    n = key.shape[0]
    k = min(k, n)
    if k == 0:
        return np.empty(0, dtype=np.int64)
    if k < n:
        part = np.argpartition(key, k - 1)[:k]
        cand = np.nonzero(key <= key[part].max())[0]
    else:
        cand = np.arange(n)
    order = np.lexsort((indices[cand], key[cand]))[:k]
    return cand[order]


@dataclass(eq=False)
class SweepResult(_SweepIndexing):
    """Objective arrays for a full cross-product sweep, plus lazy
    :class:`DesignPoint` materialization.

    ``axes`` is the ordered list of (name, values) swept dimensions; flat
    arrays are C-ordered over ``shape``, so axis values for point ``i`` are
    recovered with ``np.unravel_index`` — no per-point objects exist until
    :meth:`design_point` is called for a survivor.
    """
    axes: Tuple[Tuple[str, Tuple], ...]
    shape: Tuple[int, ...]
    workloads: Tuple[AccelWorkload, ...]
    n_tg: int
    throughput: np.ndarray              # (N,) float64, total across accels
    area: np.ndarray                    # (N,) float64
    energy_per_unit: np.ndarray         # (N,) float64
    valid: np.ndarray                   # (N,) bool (placement collisions out)
    mem_traffic: Optional[np.ndarray] = None   # (N,) float64, Fig.-4 model
    elapsed_s: float = 0.0
    backend: str = "numpy"

    def __len__(self) -> int:
        return int(self.throughput.shape[0])

    @property
    def n_valid(self) -> int:
        return int(self.valid.sum())

    @property
    def points_per_second(self) -> float:
        return len(self) / self.elapsed_s if self.elapsed_s > 0 else float("inf")

    def objective_values(self, objective: str, indices) -> np.ndarray:
        """Objective array values at flat ``indices`` (dense lookup)."""
        return getattr(self, objective)[np.asarray(indices, dtype=np.int64)]

    def pareto_indices(self) -> np.ndarray:
        """Flat indices of the (valid-only) Pareto front, O(N log N)."""
        flat = np.nonzero(self.valid)[0]
        sub = pareto_front_indices(self.throughput[flat], self.area[flat],
                                   self.energy_per_unit[flat])
        return flat[sub]

    def topk_indices(self, k: int, objective: str = "throughput",
                     maximize: Optional[bool] = None) -> np.ndarray:
        """Flat indices of the k best valid points on one objective,
        best-first, via argpartition (no full sort, no DesignPoints).
        Exact ties order by ascending flat index (the same deterministic
        tie-break the chunked sweep's running top-k merge uses)."""
        vals = getattr(self, objective)
        if maximize is None:
            maximize = objective == "throughput"
        flat = np.nonzero(self.valid)[0]
        v = vals[flat]
        key = -v if maximize else v
        return flat[_topk_select(key, flat, k)]


@dataclass(eq=False)
class ChunkedSweepResult(_SweepIndexing):
    """Survivors of a chunked/streaming :func:`grid_sweep`.

    The full grid (``len(self)`` points, possibly >1e8) was evaluated in
    fixed-size axis blocks and never materialized whole; only the running
    Pareto front and the per-objective top-``topk_track`` survivors are
    retained, with **globally addressable** flat indices — the same
    C-order over ``shape`` a one-shot :class:`SweepResult` uses, so
    :meth:`axis_values` / :meth:`design_point` / downstream consumers
    (``closed_loop_score``, ``BatchSimPlatform.from_design_points``) work
    unchanged.  Objective *values* are only retained for tracked
    survivors: :meth:`objective_values` raises ``KeyError`` for other
    indices, and :meth:`design_point` on an untracked index still decodes
    replication/placement/rates exactly but carries NaN objectives.
    """
    axes: Tuple[Tuple[str, Tuple], ...]
    shape: Tuple[int, ...]
    workloads: Tuple[AccelWorkload, ...]
    n_tg: int
    n_points: int
    n_valid: int
    cand_indices: np.ndarray            # (M,) int64, sorted ascending
    cand_values: Dict[str, np.ndarray]  # objective -> (M,) float64
    pareto: np.ndarray                  # (F,) int64 global, ascending
    topk: Dict[str, np.ndarray]         # objective -> best-first global idx
    topk_track: int
    chunk_points: int
    n_chunks: int
    peak_chunk_bytes: int
    elapsed_s: float = 0.0
    backend: str = "numpy"

    def __len__(self) -> int:
        return self.n_points

    @property
    def points_per_second(self) -> float:
        return len(self) / self.elapsed_s if self.elapsed_s > 0 else float("inf")

    def objective_values(self, objective: str, indices) -> np.ndarray:
        """Objective values at flat ``indices`` — tracked survivors only."""
        idx = np.atleast_1d(np.asarray(indices, dtype=np.int64))
        pos = np.searchsorted(self.cand_indices, idx)
        ok = (pos < self.cand_indices.shape[0]) \
            & (self.cand_indices[np.minimum(
                pos, self.cand_indices.shape[0] - 1)] == idx)
        if not ok.all():
            raise KeyError(
                f"flat indices {idx[~ok][:5].tolist()} are not tracked "
                "survivors of this chunked sweep (only Pareto/top-k points "
                "retain objective values)")
        return self.cand_values[objective][pos]

    def _point_objectives(self, i: int) -> Tuple[float, float, float]:
        """Tracked survivors report their stored objectives; any other
        (still decodable) index degrades to NaN objectives rather than
        refusing to materialize."""
        try:
            return _SweepIndexing._point_objectives(self, i)
        except KeyError:
            return (float("nan"),) * 3

    def pareto_indices(self) -> np.ndarray:
        """Global flat indices of the full-grid Pareto front (the running
        block merge is exact: front(union) == front(union of block
        fronts)), ascending — identical to the one-shot sweep's."""
        return self.pareto

    def topk_indices(self, k: int, objective: str = "throughput",
                     maximize: Optional[bool] = None) -> np.ndarray:
        """Best-first global indices on one objective, ``k <= topk_track``.
        Identical to the one-shot sweep's (ties broken by flat index)."""
        default = dict(_TRACKED_OBJECTIVES)
        if maximize is None:
            maximize = objective == "throughput"
        if objective not in default or maximize != default[objective]:
            raise KeyError(
                f"chunked sweeps track top-k only for {sorted(default)} in "
                "their default directions")
        if k > self.topk_track:
            raise ValueError(
                f"k={k} exceeds topk_track={self.topk_track} retained by "
                "this chunked sweep; re-run grid_sweep with a larger "
                "topk_track")
        return self.topk[objective][:k]


def _axis(values, dim: int, ndim: int) -> np.ndarray:
    """Reshape a 1-D axis to broadcast at dimension ``dim`` of ``ndim``."""
    a = np.asarray(values)
    shape = [1] * ndim
    shape[dim] = a.shape[0]
    return a.reshape(shape)


@dataclass(frozen=True)
class _AxisLayout:
    """Dimension layout of one sweep: per-accel K axes, ``f_noc``, the
    shared or per-accel ``f_acc`` axes, ``f_tg``, per-accel pos axes,
    plus an optional trailing combined ``tech`` axis (node, variant)."""
    A: int
    independent: bool
    tech: bool = False

    @property
    def R(self) -> int:
        return self.A if self.independent else 1

    @property
    def ndim(self) -> int:
        return 2 * self.A + self.R + 2 + (1 if self.tech else 0)

    @property
    def tdim(self) -> int:
        assert self.tech, "no tech axis in this sweep"
        return 2 * self.A + self.R + 2

    def k(self, a: int) -> int:
        return a

    @property
    def fnoc(self) -> int:
        return self.A

    def fa(self, a: int) -> int:
        return self.A + 1 + (a if self.independent else 0)

    @property
    def ftg(self) -> int:
        return self.A + 1 + self.R

    def pos(self, a: int) -> int:
        return self.A + 2 + self.R + a


def _eval_grid(model: SoCPerfModel, workloads, n_tg: int, backend: str,
               lay: _AxisLayout, vals: Dict[str, object], get,
               shape: Tuple[int, ...]) -> Dict[str, np.ndarray]:
    """Evaluate every objective over one (sub-)grid.

    ``get(dim, values)`` returns the broadcastable array of an axis for
    this block; the arithmetic is purely elementwise + fixed-order accel
    loops, so any blocking of the grid produces bit-identical floats —
    the chunked sweep's correctness contract.  The energy model routes the
    shared-rate case through the *same* per-accel op sequence as the
    independent case (sum over accel islands in order, then /A), which is
    what makes all-islands-equal independent points reproduce the shared
    sweep bit for bit.
    """
    A = lay.A
    k_ax = [get(lay.k(a), vals["k"]) for a in range(A)]
    fn_ax = get(lay.fnoc, vals["noc"])
    fa_ax = [get(lay.fa(a), vals["acc"][a]) for a in range(A)]
    ft_ax = get(lay.ftg, vals["tg"])
    pos_ax = [get(lay.pos(a), vals["pos"]) for a in range(A)]

    total_thr = np.zeros(shape, dtype=np.float64)
    for a, wl in enumerate(workloads):
        thr = model.accel_throughput_batch(
            base_mbps=wl.base_mbps, wire_share=wl.wire_share, k=k_ax[a],
            f_acc=fa_ax[a], f_noc=fn_ax, f_tg=ft_ax, n_tg=n_tg,
            pos_idx=pos_ax[a], backend=backend)
        total_thr = total_thr + np.broadcast_to(thr, shape)

    area = np.zeros(shape, dtype=np.float64)
    for a in range(A):
        area = area + get(lay.k(a), vals["area"])

    # mean accelerator-island power (summed in accel order, then /A) +
    # the NoC share — one op sequence for both island_rates modes
    if lay.tech:
        # physical V^2 f model: per-tech-axis (p_scale, v0, v1) coefficients
        ps = get(lay.tdim, vals["tech_ps"])
        v0 = get(lay.tdim, vals["tech_v0"])
        v1 = get(lay.tdim, vals["tech_v1"])
        pw = chip_power_coeffs(fa_ax[0], 1.0, v0, v1, ps)
        for f in fa_ax[1:]:
            pw = pw + chip_power_coeffs(f, 1.0, v0, v1, ps)
        power = pw / float(A) \
            + NOC_POWER_SHARE * chip_power_coeffs(fn_ax, 1.0, v0, v1, ps)
    else:
        pw = chip_power(fa_ax[0], busy=1.0)
        for f in fa_ax[1:]:
            pw = pw + chip_power(f, busy=1.0)
        power = pw / float(A) + NOC_POWER_SHARE * chip_power(fn_ax, busy=1.0)
    energy = np.broadcast_to(power, shape) / np.maximum(total_thr, 1e-9)

    # Fig.-4 memory-pressure objective: offered MEM traffic at each rate
    # point (placement-independent, so it broadcasts over the K/pos axes)
    mem_traffic = np.broadcast_to(
        model.memory_traffic_batch(f_acc_per_accel=fa_ax, f_noc=fn_ax,
                                   f_tg=ft_ax, n_tg=n_tg), shape)

    valid = np.ones(shape, dtype=bool)
    for a in range(A):
        for b in range(a + 1, A):
            valid &= pos_ax[a] != pos_ax[b]

    return {"throughput": total_thr,
            "area": np.ascontiguousarray(np.broadcast_to(area, shape)),
            "energy_per_unit": energy,
            "mem_traffic": np.ascontiguousarray(mem_traffic),
            "valid": valid}


# bounded: one executable per (device count, model constants) combination
# actually swept in this process — keyed on scalars only, never arrays
@lru_cache(maxsize=8)
def _flat_point_evaluator(n_devices: int, A: int, n_tg: int,
                          base_wire: Tuple[Tuple[float, float], ...],
                          own_demand: float, tg_demand: float,
                          link_bw: float, hop_latency_share: float,
                          ref_hops: float, mem_service: float,
                          tg_demand_fig4: float, tech: bool = False):
    """jit-compiled (and, for ``n_devices > 1``, ``shard_map``-sharded)
    evaluator of the three float objectives over a flat (P,) point axis.

    The math is the same fixed-order accel loop as :func:`_eval_grid`
    (``_throughput_math`` / ``chip_power`` / the per-accel Fig.-4 memory
    model), expressed in jax so the point axis can be partitioned across
    devices.  Sharding only splits an elementwise computation, so every
    device count produces identical floats — tested 1-vs-N in
    ``tests/test_shard_pallas.py``.  Runs at jax default precision (f32),
    so results deviate ~1e-6 relative from the numpy f64 path, which
    stays the ground truth for ``devices=None``.

    ``tech=True`` compiles the physical-DVFS variant: three extra (P,)
    inputs ``(p_scale, v0, v1)`` — one tech coefficient triple per point —
    replace the linear voltage proxy in the power term.
    """
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro import shard as shard_mod
    from jax.sharding import PartitionSpec

    def _thr_mem(kA, faA, f_noc, f_tg, hopA):
        thr = jnp.zeros_like(f_noc)
        for a, (base, wire) in enumerate(base_wire):
            thr = thr + _throughput_math(
                jnp, base, wire, kA[a], faA[a], f_noc, f_tg, n_tg, hopA[a],
                own_demand=own_demand, tg_demand=tg_demand, link_bw=link_bw,
                hop_latency_share=hop_latency_share, ref_hops=ref_hops)
        mem = _memory_traffic_math_per_accel(
            jnp, [faA[a] for a in range(A)], f_noc, f_tg, n_tg,
            mem_service=mem_service, tg_demand_fig4=tg_demand_fig4)
        return thr, mem

    if tech:
        def fn(kA, faA, hopA, f_noc, f_tg, ps, v0, v1):
            thr, mem = _thr_mem(kA, faA, f_noc, f_tg, hopA)
            pw = chip_power_coeffs(faA[0], 1.0, v0, v1, ps)
            for a in range(1, A):
                pw = pw + chip_power_coeffs(faA[a], 1.0, v0, v1, ps)
            power = pw / float(A) \
                + NOC_POWER_SHARE * chip_power_coeffs(f_noc, 1.0, v0, v1, ps)
            energy = power / jnp.maximum(thr, 1e-9)
            return thr, energy, mem
        n_in = 8
    else:
        def fn(kA, faA, hopA, f_noc, f_tg):
            thr, mem = _thr_mem(kA, faA, f_noc, f_tg, hopA)
            pw = chip_power(faA[0], busy=1.0)
            for a in range(1, A):
                pw = pw + chip_power(faA[a], busy=1.0)
            power = pw / float(A) + NOC_POWER_SHARE * chip_power(f_noc,
                                                                busy=1.0)
            energy = power / jnp.maximum(thr, 1e-9)
            return thr, energy, mem
        n_in = 5

    if n_devices <= 1:
        return jax.jit(fn)
    mesh = shard_mod.device_mesh(n_devices, "points")
    s2 = PartitionSpec(None, "points")
    s1 = PartitionSpec("points")
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(s2, s2, s2) + (s1,) * (n_in - 3),
        out_specs=(s1, s1, s1), check_vma=False))


def _eval_flat_points(model: SoCPerfModel, workloads, n_tg: int,
                      lay: _AxisLayout, vals: Dict[str, object],
                      shape: Tuple[int, ...], lo: int, hi: int,
                      n_devices: int) -> Dict[str, np.ndarray]:
    """Evaluate global flat points ``[lo, hi)`` as flat (P,) arrays.

    The per-point axis gathers, the area sum and the placement-validity
    mask stay host-side (cheap integer work, bit-identical regardless of
    device count); the float objective math runs through the sharded
    :func:`_flat_point_evaluator`.  The point axis is padded to a device
    multiple (padded lanes replicate point 0 and are sliced off).
    """
    from repro import shard as shard_mod

    coords = np.unravel_index(np.arange(lo, hi), shape)
    A = lay.A
    P = hi - lo
    kA = np.stack([np.asarray(vals["k"])[coords[lay.k(a)]]
                   for a in range(A)])
    faA = np.stack([np.asarray(vals["acc"][a])[coords[lay.fa(a)]]
                    for a in range(A)])
    posA = np.stack([np.asarray(vals["pos"])[coords[lay.pos(a)]]
                     for a in range(A)])
    hopA = np.stack([model.hop_counts(pos_idx=posA[a])
                     for a in range(A)]).astype(np.float64)
    f_noc = np.asarray(vals["noc"])[coords[lay.fnoc]]
    f_tg = np.asarray(vals["tg"])[coords[lay.ftg]]

    area = np.zeros(P, dtype=np.float64)
    for a in range(A):
        area += np.asarray(vals["area"])[coords[lay.k(a)]]
    valid = np.ones(P, dtype=bool)
    for a in range(A):
        for b in range(a + 1, A):
            valid &= posA[a] != posA[b]

    evaluator = _flat_point_evaluator(
        int(n_devices), A, int(n_tg),
        tuple((float(wl.base_mbps), float(wl.wire_share))
              for wl in workloads),
        float(model.own_demand), float(model.tg_demand),
        float(model.noc.link_bw), float(model.hop_latency_share),
        float(model._ref_hops()), float(model.mem_service),
        float(model.tg_demand_fig4), tech=lay.tech)

    def pad(x: np.ndarray) -> np.ndarray:
        return shard_mod.pad_axis(x, n_devices, axis=x.ndim - 1)

    args = [pad(kA), pad(faA), pad(hopA), pad(f_noc), pad(f_tg)]
    if lay.tech:
        tc = coords[lay.tdim]
        args += [pad(np.asarray(vals[n])[tc])
                 for n in ("tech_ps", "tech_v0", "tech_v1")]
    thr, energy, mem = evaluator(*args)
    return {"throughput": np.asarray(thr)[:P].astype(np.float64),
            "area": area,
            "energy_per_unit": np.asarray(energy)[:P].astype(np.float64),
            "mem_traffic": np.asarray(mem)[:P].astype(np.float64),
            "valid": valid}


def _prepare_axes(model, workloads, ks, acc_rates, noc_rates, tg_rates,
                  positions, island_rates, tech_node=None,
                  tech_variant=None):
    """Axis bookkeeping shared by the one-shot and chunked paths."""
    assert island_rates in ("shared", "independent"), island_rates
    independent = island_rates == "independent"

    # tech_node / tech_variant combine into ONE trailing "tech" axis whose
    # values are (node, variant) pairs — the cross product of both inputs —
    # so the 1-D axis broadcast/chunk machinery applies unchanged
    techs: Tuple[Tuple[int, str], ...] = ()
    if tech_node is not None or tech_variant is not None:
        nodes = 45 if tech_node is None else tech_node
        if isinstance(nodes, (int, np.integer)):
            nodes = (nodes,)
        variants = "itrs" if tech_variant is None else tech_variant
        if isinstance(variants, str):
            variants = (variants,)
        techs = tuple((int(n), str(v)) for n in nodes for v in variants)
    if positions is None:
        positions = [(r, c) for r in range(model.noc.rows)
                     for c in range(model.noc.cols)
                     if (r, c) != model.mem_pos]
    positions = [tuple(p) for p in positions]
    pos_idx = np.asarray([pos_index(model.noc, p) for p in positions])

    if isinstance(acc_rates, dict):
        assert independent, "per-accel acc_rates ladders require " \
            "island_rates='independent'"
        acc_by_wl = [tuple(float(f) for f in acc_rates[wl.name])
                     for wl in workloads]
    else:
        acc_by_wl = [tuple(float(f) for f in acc_rates)] * len(workloads)

    A = len(workloads)
    lay = _AxisLayout(A=A, independent=independent, tech=bool(techs))
    axes: List[Tuple[str, Tuple]] = []
    for wl in workloads:
        axes.append((f"K:{wl.name}", tuple(int(k) for k in ks)))
    axes.append(("f_noc", tuple(float(f) for f in noc_rates)))
    if independent:
        for a, wl in enumerate(workloads):
            axes.append((f"f_acc:{wl.name}", acc_by_wl[a]))
    else:
        axes.append(("f_acc", acc_by_wl[0]))
    axes.append(("f_tg", tuple(float(f) for f in tg_rates)))
    for wl in workloads:
        axes.append((f"pos:{wl.name}", tuple(positions)))
    if techs:
        axes.append(("tech", techs))

    area_by_k = {int(k): replication_area_model(
        weight_bytes=1.0, act_bytes=0.5, k=int(k))["total_bytes_per_dev"]
        for k in ks}
    vals = {
        "k": np.asarray([float(k) for k in ks]),
        "area": np.asarray([area_by_k[int(k)] for k in ks]),
        "noc": np.asarray([float(f) for f in noc_rates]),
        "tg": np.asarray([float(f) for f in tg_rates]),
        "acc": [np.asarray(r) for r in acc_by_wl],
        "pos": pos_idx,
    }
    if techs:
        vals.update(tech_axis_coeffs(techs))
    return lay, tuple(axes), vals


def _front_prefilter(thr: np.ndarray, area: np.ndarray, energy: np.ndarray,
                     max_classes: int = 1024) -> np.ndarray:
    """Positions of a cheap *superset* of the 3-objective Pareto front.

    Per distinct-area class (area takes one value per K combination — a
    handful), the 2-objective (max throughput, min energy) staircase via
    one lexsort + cumulative min; any point dominated there is dominated
    in 3D by the same point (equal area), so the exact — but per-point
    Python — :func:`pareto_front_indices` scan afterwards only sees the
    small candidate set.  This is what keeps the chunked sweep's per-block
    front extraction vectorized at millions of points per block.  Falls
    back to the identity when area is effectively continuous."""
    uniq = np.unique(area)
    if uniq.shape[0] > max_classes:
        return np.arange(thr.shape[0])
    keep: List[np.ndarray] = []
    for av in uniq:
        sel = np.nonzero(area == av)[0]
        o = sel[np.lexsort((energy[sel], -thr[sel]))]
        cm = np.minimum.accumulate(energy[o])
        keep.append(o[energy[o] <= cm])     # over-keeps ties; exact scan next
    return np.concatenate(keep)


def _merge_front(cand: Dict[str, np.ndarray],
                 rows: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Fold one block's Pareto survivors into the running front."""
    merged = {k: np.concatenate([cand[k], rows[k]]) for k in cand}
    keep = pareto_front_indices(merged["throughput"], merged["area"],
                                merged["energy_per_unit"])
    return {k: v[keep] for k, v in merged.items()}


def grid_sweep(model: SoCPerfModel,
               workloads,
               *,
               ks: Sequence[int] = (1, 2, 4),
               acc_rates=(0.2, 0.6, 1.0),
               noc_rates: Sequence[float] = (0.1, 0.5, 1.0),
               tg_rates: Sequence[float] = (1.0,),
               positions: Optional[Sequence[Tuple[int, int]]] = None,
               n_tg: int = 0,
               backend: str = "numpy",
               island_rates: str = "shared",
               chunk_points: Optional[int] = None,
               topk_track: int = 64,
               devices=None,
               tech_node=None,
               tech_variant=None):
    """Batched cross-product sweep over the paper's design axes.

    ``workloads`` is one :class:`AccelWorkload` or a sequence for a *joint*
    multi-accelerator sweep (each accelerator gets its own K axis and its
    own placement axis).  The swept dimensions, in axis order, are::

        island_rates="shared":       K:<wl> | f_noc | f_acc        | f_tg | pos:<wl>
        island_rates="independent":  K:<wl> | f_noc | f_acc:<wl>.. | f_tg | pos:<wl>

    **Per-island rates** (the paper's C2): with
    ``island_rates="independent"`` every accelerator island sweeps its own
    rate ladder — one ``f_acc:<wl>`` axis per accelerator — instead of the
    one shared ``f_acc`` axis (kept as the parity reference); ``acc_rates``
    may then also be a ``{workload name: ladder}`` mapping for
    heterogeneous ladders.  Restricted to all-islands-equal rates the
    independent sweep reproduces the shared sweep bit for bit (tested).

    ``positions`` defaults to every grid node except the MEM tile.  Joint
    placements where two accelerators collide are masked invalid (their
    objective entries are still computed — the arrays stay rectangular —
    but :meth:`SweepResult.pareto_indices` / ``topk_indices`` skip them).

    Throughput of a joint point is the sum of the accelerators' modeled
    throughputs; area sums each accelerator's replication cost; energy is
    the mean accelerator-island chip power (each island at its own rate)
    plus the NoC share, per unit of total throughput; ``mem_traffic`` sums
    each accelerator's offered MEM stream at its own island rate.  With
    ``backend="jax"`` the throughput kernel runs jit-compiled.

    **Chunked/streaming evaluation**: when ``chunk_points`` is given and
    the cross-product exceeds it, the grid is evaluated in fixed-size
    axis blocks (whole trailing-axis panels, so every block is a
    contiguous range of global flat indices) with a running Pareto/top-k
    merge, and a :class:`ChunkedSweepResult` is returned — peak memory is
    ~``41 * chunk_points`` bytes (five float64 objective/temp panels + a
    bool mask) however large the full grid is, while indices stay globally
    addressable and Pareto front / top-k are identical to a one-shot
    sweep (tested).  Otherwise a dense :class:`SweepResult` is returned.

    **Multi-device sharding**: ``devices=`` (``None`` / int / ``"auto"``,
    see :func:`repro.shard.resolve_devices`) switches each block (or the
    whole grid on the dense path) to a flat per-point jax evaluator whose
    point axis is ``shard_map``-partitioned across devices.  Any device
    count — including 1 — produces identical floats (sharding only splits
    elementwise math); ``devices=None`` keeps the numpy float64 path as
    the bit-for-bit ground truth, against which the jax float32 path
    deviates ~1e-6 relative.  Multi-device CPU runs need
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before the
    first jax import.

    **Physical DVFS** (``tech_node=`` / ``tech_variant=``): passing a node
    (int or sequence from :data:`repro.core.voltage.TECH_NODES`) and/or a
    scaling variant (``"itrs"``/``"cons"`` or a sequence) appends one
    trailing ``tech`` axis — the (node, variant) cross product — and
    switches the energy objective from the linear voltage proxy to the
    physical ``power_scl * (P_static + P_dyn f V̂(f)^2)`` model of
    :class:`repro.core.voltage.TechModel`.  Throughput/area/mem_traffic
    are tech-invariant (the grid anchors to the measured Table-I rates);
    the axis streams through ``chunk_points=`` and shards through
    ``devices=`` like any other.  ``tech_node=None`` (the default) keeps
    today's linear model bit for bit.
    """
    if isinstance(workloads, AccelWorkload):
        workloads = (workloads,)
    workloads = tuple(workloads)
    lay, axes, vals = _prepare_axes(model, workloads, ks, acc_rates,
                                    noc_rates, tg_rates, positions,
                                    island_rates, tech_node=tech_node,
                                    tech_variant=tech_variant)
    ndim = lay.ndim
    shape = tuple(len(v) for _, v in axes)
    n_points = int(np.prod([len(v) for _, v in axes], dtype=np.int64))

    n_devices = 0
    if devices is not None:
        from repro import shard as shard_mod
        n_devices = shard_mod.resolve_devices(devices)

    t0 = time.perf_counter()
    if chunk_points is None or n_points <= chunk_points:
        if n_devices:
            out = _eval_flat_points(model, workloads, n_tg, lay, vals,
                                    shape, 0, n_points, n_devices)
        else:
            get = lambda dim, v: _axis(v, dim, ndim)    # noqa: E731
            out = _eval_grid(model, workloads, n_tg, backend, lay, vals,
                             get, shape)
        elapsed = time.perf_counter() - t0
        return SweepResult(
            axes=axes, shape=shape, workloads=workloads, n_tg=n_tg,
            throughput=out["throughput"].ravel(),
            area=out["area"].ravel(),
            energy_per_unit=out["energy_per_unit"].ravel(),
            valid=out["valid"].ravel(),
            mem_traffic=out["mem_traffic"].ravel(),
            elapsed_s=elapsed, backend=backend)

    # ---- chunked/streaming path: fixed-size blocks of whole trailing
    # panels; every block covers the contiguous global flat range
    # [o0*inner, o1*inner) so survivors carry global indices for free
    inner = 1
    s = ndim
    while s > 0 and inner * shape[s - 1] <= chunk_points:
        inner *= shape[s - 1]
        s -= 1
    outer_shape = shape[:s]
    outer_n = int(np.prod(outer_shape, dtype=np.int64)) if s else 1
    o_per_block = max(1, chunk_points // max(inner, 1))

    objs = [name for name, _ in _TRACKED_OBJECTIVES]
    empty = {"i": np.empty(0, dtype=np.int64),
             **{o: np.empty(0, dtype=np.float64) for o in objs}}
    front = dict(empty)
    topk = {o: dict(empty) for o in objs}
    n_valid = 0
    n_chunks = 0
    peak_bytes = 0

    try:
        # lazy: the core DSE layer stays importable without repro.sim
        from repro.sim.observe import profiled as _profiled
    except ImportError:                              # pragma: no cover
        import contextlib

        def _profiled(name):
            return contextlib.nullcontext()

    for o0 in range(0, outer_n, o_per_block):
        o1 = min(o0 + o_per_block, outer_n)
        O = o1 - o0
        coords = np.unravel_index(np.arange(o0, o1), outer_shape)
        blk_ndim = ndim - s + 1

        def get(dim, v, coords=coords, O=O):
            v = np.asarray(v)
            if dim < s:
                return v[coords[dim]].reshape((O,) + (1,) * (ndim - s))
            bshape = [1] * blk_ndim
            bshape[dim - s + 1] = v.shape[0]
            return v.reshape(bshape)

        blk_shape = (O,) + shape[s:]
        with _profiled("sweep_chunk"):
            if n_devices:
                flat = _eval_flat_points(model, workloads, n_tg, lay, vals,
                                         shape, o0 * inner, o1 * inner,
                                         n_devices)
            else:
                out = _eval_grid(model, workloads, n_tg, backend, lay,
                                 vals, get, blk_shape)
                flat = {k: v.ravel() for k, v in out.items()}
        n_chunks += 1
        peak_bytes = max(peak_bytes, sum(v.nbytes for v in flat.values())
                         + flat["throughput"].nbytes)   # + kernel temp

        vpos = np.nonzero(flat["valid"])[0]
        n_valid += int(vpos.size)
        if vpos.size == 0:
            continue
        rows = {"i": o0 * inner + vpos,
                **{o: flat[o][vpos] for o in objs}}

        pre = _front_prefilter(rows["throughput"], rows["area"],
                               rows["energy_per_unit"])
        bf = pre[pareto_front_indices(rows["throughput"][pre],
                                      rows["area"][pre],
                                      rows["energy_per_unit"][pre])]
        front = _merge_front(front, {k: v[bf] for k, v in rows.items()})
        for o, maximize in _TRACKED_OBJECTIVES:
            key = -rows[o] if maximize else rows[o]
            sel = _topk_select(key, rows["i"], topk_track)
            cat = {k: np.concatenate([topk[o][k], v[sel]])
                   for k, v in rows.items()}
            ckey = -cat[o] if maximize else cat[o]
            keep = _topk_select(ckey, cat["i"], topk_track)
            topk[o] = {k: v[keep] for k, v in cat.items()}

    # assemble the tracked-survivor store: pareto ∪ top-k, deduped
    pools = [front] + [topk[o] for o in objs]
    all_idx = np.concatenate([p["i"] for p in pools])
    uniq, upos = np.unique(all_idx, return_index=True)
    cand_values = {o: np.concatenate([p[o] for p in pools])[upos]
                   for o in objs}
    elapsed = time.perf_counter() - t0
    return ChunkedSweepResult(
        axes=axes, shape=shape, workloads=workloads, n_tg=n_tg,
        n_points=n_points, n_valid=n_valid,
        cand_indices=uniq, cand_values=cand_values,
        pareto=np.sort(front["i"]),
        topk={o: topk[o]["i"] for o in objs},
        topk_track=topk_track, chunk_points=chunk_points,
        n_chunks=n_chunks, peak_chunk_bytes=int(peak_bytes),
        elapsed_s=elapsed, backend=backend)


# ---------------------------------------------------------------------------
# Closed-loop re-ranking: the static sweep meets the runtime simulator
# ---------------------------------------------------------------------------


@dataclass
class ClosedLoopScore:
    """Simulated runtime scores for a set of sweep survivors.

    ``indices`` are flat :class:`SweepResult` indices; the parallel arrays
    hold each point's simulated p99 latency, energy per request and
    sustained throughput under the replayed trace.  ``order`` re-ranks
    ``indices`` best-first: points meeting the p99 SLA sorted by energy
    per request, then SLA violators by how badly they miss it.

    ``results`` holds per-point ``sim.SimResult`` objects on the
    sequential path; on the batched path it holds the single
    ``sim.BatchSimResult`` of the one stacked replay.

    ``counters`` (only when ``observe=`` enabled the monitoring plane) is
    one ``sim.CounterPlane.summary()`` dict per survivor — utilization,
    stall fraction, NoC flits, per-island energy — aligned with
    ``indices``.
    """
    indices: np.ndarray                 # (M,) int64
    p99_latency_s: np.ndarray           # (M,) float64
    energy_per_request_j: np.ndarray    # (M,) float64
    throughput_rps: np.ndarray          # (M,) float64
    order: np.ndarray                   # (M,) int64 positions into indices
    results: List[object]               # SimResults, or one BatchSimResult
    drop_rate: Optional[np.ndarray] = None   # (M,) under a fault schedule
    counters: Optional[List[Dict[str, float]]] = None   # (M,) summaries

    def ranked_indices(self) -> np.ndarray:
        """Flat SweepResult indices, best-first."""
        return self.indices[self.order]


def _rank_scores(p99: np.ndarray, ept: np.ndarray,
                 p99_sla_s: Optional[float],
                 drop_rate: Optional[np.ndarray] = None,
                 max_drop_rate: Optional[float] = None) -> np.ndarray:
    """Best-first order: SLO-miss severity (p99 miss + drop-budget miss),
    then energy.  Without SLO bounds the legacy (energy, p99) order is
    unchanged; ``drop_rate`` only participates when given (fault-aware
    scoring), so fault-free rankings are untouched.

    Degenerate survivors — zero-completion runs reporting NaN energy per
    request and/or NaN p99 — always rank last via an explicit mask (their
    NaN channels carry no information, and ``np.lexsort``'s NaN placement
    in non-primary keys is not a contract we want to lean on)."""
    p99 = np.asarray(p99, dtype=np.float64)
    ept = np.asarray(ept, dtype=np.float64)
    degenerate = np.isnan(p99) | np.isnan(ept)
    p99 = np.where(degenerate, np.inf, p99)
    ept = np.where(degenerate, np.inf, ept)
    if p99_sla_s is not None or max_drop_rate is not None:
        miss = np.zeros_like(ept)
        if p99_sla_s is not None:
            miss = miss + np.maximum(0.0, p99 / p99_sla_s - 1.0)
        if max_drop_rate is not None and drop_rate is not None:
            miss = miss + np.maximum(0.0, drop_rate / max_drop_rate - 1.0)
        return np.lexsort((ept, miss, degenerate))   # SLO first, then energy
    if drop_rate is not None:
        # fault-aware but unbudgeted: robustness outranks energy
        return np.lexsort((ept, p99, drop_rate, degenerate))
    return np.lexsort((p99, ept, degenerate))  # energy first, p99 tie-break


def closed_loop_score(result: SweepResult, trace, *,
                      model: SoCPerfModel,
                      indices: Optional[Sequence[int]] = None,
                      top: int = 8,
                      p99_sla_s: Optional[float] = None,
                      controller_factory=None,
                      batch_controller_factory=None,
                      req_mb: float = 0.1,
                      sim_config=None,
                      batch: Optional[bool] = None,
                      backend: str = "numpy",
                      trace_seed: int = 0,
                      flows=None,
                      balancer_factory=None,
                      fault_schedule=None,
                      slo=None,
                      max_drop_rate: Optional[float] = None,
                      observe=None,
                      devices=None,
                      tech=None
                      ) -> ClosedLoopScore:
    """Re-rank static-sweep survivors by *simulated* runtime behaviour.

    The static objectives of :func:`grid_sweep` assume steady saturated
    streams; under dynamic traffic two points with equal static throughput
    can have wildly different tail latency and idle-power profiles.  This
    bridge replays ``trace`` (a ``repro.sim.Trace`` whose destinations map
    1:1 to ``result.workloads``) through each survivor — by default the
    ``top`` throughput points of the Pareto front — with an optional
    online DFS controller in the loop, and ranks by (p99 SLA met, energy
    per request).  The static sweep and the runtime loop become one
    pipeline::

        res   = grid_sweep(model, wls, ...)
        score = closed_loop_score(res, diurnal_trace(...), model=model,
                                  p99_sla_s=0.05)
        best  = res.design_point(int(score.ranked_indices()[0]))

    **Batched by default**: the survivors are stacked into one
    ``repro.sim.BatchSimPlatform`` and replayed as a single array program
    (``backend="numpy"`` or ``"jax"`` for the ``lax.scan`` tick loop) —
    re-ranking ~1k survivors is one batched run, not ~1k sequential sims.
    ``batch_controller_factory`` receives the stacked platform and must
    return a ``repro.sim.BatchControllerHarness`` (or None).  Passing the
    legacy per-point ``controller_factory`` (a
    ``repro.sim.ControllerHarness`` per materialized ``SimPlatform``)
    selects the sequential path, as does ``batch=False``; the sequential
    path is the differential-test reference and produces identical
    rankings (tested).  ``devices=`` (``None`` / int / ``"auto"``) shards
    the batched jax scan's design axis across devices via ``shard_map`` —
    bitwise identical to the single-device jax run at any device count.

    Determinism: ``trace`` may be a callable ``trace(seed) -> Trace``; it
    is invoked with the explicit ``trace_seed``, so repeated scoring of
    the same survivors replays an identical trace instead of relying on
    whatever generator state the caller happened to have.  Imports
    ``repro.sim`` lazily — the core DSE layer stays importable without
    the simulation subsystem.

    Workload shape: ``flows`` (a ``repro.sim.FlowPattern``) scores the
    survivors under a tile-to-tile / pipeline workload instead of the
    default accelerator->MEM stream; ``balancer_factory`` (platform ->
    ``repro.sim.LoadBalancer``) puts a replica-group admission policy in
    the loop next to the DFS controller.  Both apply to the batched and
    the sequential path alike, so the differential reference covers them.
    On the batched path ``trace`` may also be a ``repro.sim.BatchTrace``
    whose design axis matches the survivor count — each survivor then
    replays its own arrival tensor.

    Robustness scoring: ``fault_schedule`` (a ``repro.sim.FaultSchedule``)
    replays every survivor through the same injected failures (tile
    kills, link degradation, stuck actuators) with ``slo`` (a
    ``repro.sim.SLOConfig``) fixing deadline/recovery semantics — the
    ranking then uses p99-*under-failure* and each survivor's drop rate
    (hard budget via ``max_drop_rate``, joining the p99 SLA in the miss
    score; otherwise as the primary sort key ahead of energy).  Fault-free
    calls rank exactly as before.

    Observability: ``observe`` (a ``repro.sim.Observer`` or a level name
    ``"counters"``/``"full"``) turns on the monitoring plane inside every
    replay; the score then carries one counter summary per survivor in
    ``ClosedLoopScore.counters`` (batched: one ``design(j)`` slice each of
    the single stacked plane).  ``observe=None`` keeps the replays
    monitoring-free and is bit-for-bit identical to pre-observability
    scoring.

    Physical DVFS: ``tech=`` (a ``repro.core.voltage.TechModel``, a node
    int, or a ``(node, variant)`` pair) replays every survivor under the
    physical ``V^2 f`` tick-energy model and clamps DFS commits to the
    node's legal ratio range — the re-ranking then reflects the tech
    node's energy landscape.  ``tech=None`` keeps the linear proxy bit
    for bit.
    """
    from repro.sim import BatchTrace, SimConfig, SimEngine, SimPlatform

    tech = TechModel.coerce(tech)
    if callable(trace):
        trace = trace(trace_seed)

    if indices is None:
        pf = result.pareto_indices()
        thr_pf = result.objective_values("throughput", pf)
        ordr = np.argsort(-thr_pf, kind="stable")
        indices = pf[ordr][:top]
    indices = np.asarray(indices, dtype=np.int64)

    if batch is None:
        batch = controller_factory is None
    assert not (batch and controller_factory is not None), \
        "per-point controller_factory requires batch=False"
    if isinstance(trace, BatchTrace):
        # each survivor replays its own tensor row — a silent mismatch
        # would pair survivor j with the wrong workload
        assert trace.n_designs == indices.shape[0], \
            (trace.n_designs, indices.shape[0])

    if batch:
        from repro.sim import BatchSimEngine, BatchSimPlatform
        platform = BatchSimPlatform.from_design_points(
            model, result, indices, req_mb=req_mb, n_tg=result.n_tg,
            flows=flows)
        controller = (batch_controller_factory(platform)
                      if batch_controller_factory is not None else None)
        engine = BatchSimEngine(platform, config=sim_config or SimConfig(),
                                controller=controller,
                                balancer=(balancer_factory(platform)
                                          if balancer_factory is not None
                                          else None),
                                backend=backend,
                                faults=fault_schedule, slo=slo,
                                observe=observe, devices=devices,
                                tech=tech)
        r = engine.run(trace)
        p99 = r.p99_latency_s
        ept = r.energy_per_request_j
        thr = r.throughput_rps
        drops = (np.asarray(r.drop_rate, dtype=np.float64)
                 if fault_schedule is not None else None)
        results: List[object] = [r]
        ob = engine.observer
        counters = (None if ob is None or ob.counters is None else
                    [ob.counters.design(j).summary()
                     for j in range(indices.shape[0])])
    else:
        p99 = np.empty(indices.shape[0])
        ept = np.empty(indices.shape[0])
        thr = np.empty(indices.shape[0])
        drops = (np.empty(indices.shape[0])
                 if fault_schedule is not None else None)
        results = []
        summaries: List[Dict[str, float]] = []
        for j, i in enumerate(indices):
            dp = result.design_point(int(i))
            platform = SimPlatform.from_design_point(
                model, dp, result.workloads, req_mb=req_mb,
                n_tg=result.n_tg, flows=flows)
            controller = (controller_factory(platform)
                          if controller_factory is not None else None)
            engine = SimEngine(platform,
                               config=sim_config or SimConfig(),
                               controller=controller,
                               balancer=(balancer_factory(platform)
                                         if balancer_factory is not None
                                         else None),
                               faults=fault_schedule, slo=slo,
                               observe=observe, tech=tech)
            r = engine.run(trace.design(j) if isinstance(trace, BatchTrace)
                           else trace)
            results.append(r)
            p99[j] = r.p99_latency_s
            ept[j] = r.energy_per_request_j
            thr[j] = r.throughput_rps
            if drops is not None:
                drops[j] = r.drop_rate
            if engine.observer is not None \
                    and engine.observer.counters is not None:
                # summarize NOW — a shared Observer instance re-attaches
                # its plane on the next survivor's run
                summaries.append(engine.observer.counters.summary())
        counters = summaries if len(summaries) == len(results) else None

    order = _rank_scores(p99, ept, p99_sla_s, drop_rate=drops,
                         max_drop_rate=max_drop_rate)
    return ClosedLoopScore(indices=indices, p99_latency_s=p99,
                           energy_per_request_j=ept, throughput_rps=thr,
                           order=np.asarray(order, dtype=np.int64),
                           results=results, drop_rate=drops,
                           counters=counters)


# ---------------------------------------------------------------------------
# Scalar reference sweep (original API)
# ---------------------------------------------------------------------------


def sweep_soc(model: SoCPerfModel, wl: AccelWorkload,
              *, ks: Sequence[int] = (1, 2, 4),
              noc_rates: Sequence[float] = (0.1, 0.5, 1.0),
              acc_rates: Sequence[float] = (0.2, 0.6, 1.0),
              positions: Sequence[Tuple[int, int]] = ((1, 1), (3, 3)),
              n_tg: int = 0) -> List[DesignPoint]:
    """Exhaustive scalar sweep over the paper's axes for one accelerator.

    The per-point reference path; :func:`grid_sweep` is the batched
    equivalent and is tested to match it within fp tolerance."""
    out: List[DesignPoint] = []
    for k, fn, fa, pos in itertools.product(ks, noc_rates, acc_rates,
                                            positions):
        w = dataclasses.replace(wl, replication=k)
        rates = {"acc": fa, "noc_mem": fn, "tg": 1.0}
        thr = model.accel_throughput(w, pos, rates, n_tg)
        area = replication_area_model(
            weight_bytes=1.0, act_bytes=0.5, k=k)["total_bytes_per_dev"]
        power = chip_power(fa, busy=1.0) \
            + NOC_POWER_SHARE * chip_power(fn, busy=1.0)
        out.append(DesignPoint(
            replication={wl.name: k}, rates=rates,
            placement={wl.name: pos}, throughput=thr, area=area,
            energy_per_unit=power / max(thr, 1e-9)))
    return out


def sweep_replication_roofline(eval_cell: Callable[[int], Dict[str, float]],
                               ks: Sequence[int] = (1, 2, 4, 8)
                               ) -> List[Dict[str, float]]:
    """Pod-scale MRA sweep: ``eval_cell(K)`` lowers/compiles the cell on the
    K-factored mesh and returns roofline terms; used by §Perf hillclimbs."""
    rows = []
    for k in ks:
        r = dict(eval_cell(k))
        r["K"] = k
        r["predicted_gain"] = replication_throughput_model(k)
        rows.append(r)
    return rows


def summarize(points: Sequence[DesignPoint], top: int = 10) -> str:
    front = pareto_front(points)
    front.sort(key=lambda p: -p.throughput)
    lines = [f"{len(points)} points, {len(front)} on Pareto front"]
    for p in front[:top]:
        lines.append(
            f"  K={p.replication}  rates={ {k: round(v, 2) for k, v in p.rates.items()} }"
            f"  pos={p.placement}  thr={p.throughput:.2f}  area={p.area:.2f}"
            f"  E/u={p.energy_per_unit:.1f}")
    return "\n".join(lines)


def summarize_result(res, top: int = 10) -> str:
    """Summary of a batched sweep (dense or chunked) without materializing
    all points."""
    front_idx = res.pareto_indices()
    order = np.argsort(-res.objective_values("throughput", front_idx),
                       kind="stable")
    lines = [f"{len(res)} points ({res.n_valid} valid, "
             f"{res.points_per_second:,.0f} pts/s), "
             f"{front_idx.shape[0]} on Pareto front"]
    for p in res.design_points(front_idx[order][:top]):
        lines.append(
            f"  K={p.replication}  rates={ {k: round(v, 2) for k, v in p.rates.items()} }"
            f"  pos={p.placement}  thr={p.throughput:.2f}  area={p.area:.2f}"
            f"  E/u={p.energy_per_unit:.1f}")
    return "\n".join(lines)
