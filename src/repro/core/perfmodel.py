"""Frequency-aware roofline performance & energy model.

Two roles:

1. **Paper-claims engine** — evaluates the paper's 4x4 SoC (CHStone tiles,
   five frequency islands) so benchmarks can reproduce Table I / Fig. 3 /
   Fig. 4 shapes analytically.

2. **Pod-scale engine** — turns dry-run artifacts (HLO FLOPs / bytes /
   collective bytes) + island rates into the three roofline terms used by
   EXPERIMENTS.md §Roofline, and into tokens/s + watts for the DFS
   energy-per-token policy.

Frequency semantics (DESIGN.md §C2): an island's normalized rate f scales
the *service rate* of its components — compute FLOP/s for accelerator
islands, link bandwidth + memory-controller service for the noc_mem island.
Energy: P(f) = P_static + P_dyn · f · V(f)^2 with V(f) = 0.7 + 0.3 f
(classic DVFS voltage scaling).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.islands import IslandConfig
from repro.core.noc import (Flow, NocConfig, NocModel,
                            collective_bytes_ring_allreduce)
from repro.core.tiles import TilePlan

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (per chip) — the assignment's numbers.
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
VMEM_BYTES = 128 * 2**20
P_STATIC_W = 60.0            # per chip, modeled
P_DYN_W = 140.0              # at f=1, modeled


def voltage(f: float) -> float:
    return 0.7 + 0.3 * f


def chip_power(f_comp: float, busy: float) -> float:
    """Modeled chip power at normalized rate f and duty cycle busy."""
    return P_STATIC_W + P_DYN_W * f_comp * voltage(f_comp) ** 2 * busy


# ---------------------------------------------------------------------------
# Roofline terms (pod-scale)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds (per step)."""
    t_compute: float
    t_memory: float
    t_collective: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """max-term / sum-of-terms: 1.0 = perfectly overlapped/bound by one
        resource; lower = time wasted on non-dominant resources if nothing
        overlaps.  (Perfect overlap means step time = t_bound.)"""
        s = self.t_compute + self.t_memory + self.t_collective
        return self.t_bound / s if s > 0 else 0.0


def roofline_from_counts(flops: float, hbm_bytes: float,
                         collective_bytes: float, chips: int,
                         *, f_comp: float = 1.0, f_noc: float = 1.0,
                         peak_flops: float = PEAK_FLOPS,
                         hbm_bw: float = HBM_BW,
                         ici_bw: float = ICI_BW) -> RooflineTerms:
    """HLO totals -> per-step roofline terms.  ``flops``/``hbm_bytes`` are
    whole-program totals; collective_bytes is per-device wire bytes."""
    return RooflineTerms(
        t_compute=flops / (chips * peak_flops * f_comp),
        t_memory=hbm_bytes / (chips * hbm_bw * f_noc),
        t_collective=collective_bytes / (ici_bw * f_noc),
        flops=flops, hbm_bytes=hbm_bytes,
        collective_bytes=collective_bytes, chips=chips)


def model_flops(n_params: int, tokens: int, *, train: bool = True) -> float:
    """The 6·N·D (train) / 2·N·D (inference) convention."""
    return (6.0 if train else 2.0) * n_params * tokens


# ---------------------------------------------------------------------------
# Paper-claims engine: CHStone accelerator tiles on the 4x4 SoC
# ---------------------------------------------------------------------------


# Per-accelerator serialized wire-interface share w, calibrated so that
# gain(K) = 1 / ((1-w)/K + w) reproduces each accelerator's measured
# Table-I throughput gains.  K replicas parallelize compute AND the
# overlappable stream latency (each replica is an independent engine
# behind the AXI bridge); only the tile's shared NoC interface serializes.
WIRE_SHARE = {
    "adpcm": 0.0005,    # strongly compute-bound: gains ~K (1.97x / 3.86x)
    "dfsin": 0.003,     # compute-bound (1.97x / 3.76x)
    "gsm": 0.035,       # mixed (1.93x / 3.62x)
    "dfadd": 0.12,      # memory-bound (1.83x / 2.83x)
    "dfmul": 0.155,     # memory-bound (1.73x / 3.00x)
}


@dataclass(frozen=True)
class AccelWorkload:
    """One CHStone accelerator processing a data stream.

    ``ai`` (arithmetic intensity, ops/byte) separates compute-bound (adpcm,
    dfsin) from memory-bound (dfadd, dfmul) accelerators, as the paper
    observed empirically.  ``base_mbps`` anchors absolute throughput to
    Table I so reproduced numbers are comparable.
    """
    name: str
    base_mbps: float
    ai: float
    replication: int = 1

    @property
    def compute_bound(self) -> bool:
        return self.ai >= 8.0

    @property
    def wire_share(self) -> float:
        if self.name in WIRE_SHARE:
            return WIRE_SHARE[self.name]
        return 0.01 if self.compute_bound else 0.14


@dataclass
class SoCPerfModel:
    """The paper's SoC: accelerator tiles + TG tiles + MEM on a 4x4 NoC,
    five frequency islands.

    Service-time model per accelerator tile:
        t(K, f) = (1 - w) / (K · f_acc)  +  w · slow · hopf / f_noc
    where ``w`` is the tile's serialized wire share (WIRE_SHARE), ``slow``
    the NoC saturation factor (proportional sharing of the f_noc-scaled
    link capacity with TG flows), and ``hopf`` a per-hop latency factor
    (placement: A1 near MEM vs A2 far, paper Fig. 2).
    """
    noc: NocConfig = field(default_factory=lambda: NocConfig(4, 4))
    mem_pos: Tuple[int, int] = (1, 0)
    mem_service: float = 8.0        # units/cycle at f_noc=1 (Fig. 4)
    tg_demand: float = 0.07         # per active TG core at f_tg=1 (Fig. 3)
    tg_demand_fig4: float = 0.5     # Fig. 4 uses heavier TG streams
    own_demand: float = 0.1
    hop_latency_share: float = 0.03

    def accel_throughput(self, wl: AccelWorkload, pos: Tuple[int, int],
                         rates: Dict[str, float], n_tg: int) -> float:
        """Throughput (MB/s) of one accelerator tile under contention."""
        f_acc = max(rates.get("acc", 1.0), 1e-3)
        f_noc = max(rates.get("noc_mem", 1.0), 1e-3)
        f_tg = rates.get("tg", 1.0)
        K = wl.replication
        w = wl.wire_share

        # NoC saturation: proportional sharing of the f_noc-scaled capacity
        load = self.own_demand + self.tg_demand * f_tg * n_tg
        cap = self.noc.link_bw * f_noc
        slow = max(1.0, load / cap)
        from repro.core.noc import hops
        hopf = 1.0 + self.hop_latency_share * hops(self.noc, pos,
                                                   self.mem_pos)

        t = (1.0 - w) / (K * f_acc) + w * slow * hopf / f_noc
        # normalize to Table I conditions (A1, K=1, f=1, no TG)
        hopf0 = 1.0 + self.hop_latency_share * hops(self.noc, (1, 1),
                                                    self.mem_pos)
        t0 = (1.0 - w) + w * max(1.0, self.own_demand) * hopf0
        return wl.base_mbps * t0 / t

    def memory_traffic_mpkts(self, rates: Dict[str, float], n_tg: int,
                             accel_positions: List[Tuple[int, int]],
                             pkt_bytes: float = 64.0) -> float:
        """Incoming memory traffic (Mpkt/s-shaped, normalized) — Fig. 4.

        TG cores offer f_tg-scaled demand; memory-bound accelerators
        saturate their stream path at low f_acc already, so traffic is
        *almost independent of f_acc* — the paper's headline observation.
        """
        f_noc = rates.get("noc_mem", 1.0)
        f_tg = rates.get("tg", 1.0)
        f_acc = rates.get("acc", 1.0)
        mem_cap = self.mem_service * f_noc
        tg_offer = self.tg_demand_fig4 * f_tg * n_tg
        acc_offer = sum(min(1.0, 5.0 * f_acc) * min(1.0, f_noc)
                        for _ in accel_positions)
        return min(mem_cap, tg_offer + acc_offer)


def _default_tg_positions(noc: NocConfig, mem: Tuple[int, int],
                          skip: Tuple[int, int]) -> List[Tuple[int, int]]:
    out = []
    for r in range(noc.rows):
        for c in range(noc.cols):
            if (r, c) in (mem, skip, (0, 0), (0, 3), (1, 1)):
                continue
            out.append((r, c))
    return out
