"""Frequency-aware roofline performance & energy model.

Two roles:

1. **Paper-claims engine** — evaluates the paper's 4x4 SoC (CHStone tiles,
   five frequency islands) so benchmarks can reproduce Table I / Fig. 3 /
   Fig. 4 shapes analytically.

2. **Pod-scale engine** — turns dry-run artifacts (HLO FLOPs / bytes /
   collective bytes) + island rates into the three roofline terms used by
   EXPERIMENTS.md §Roofline, and into tokens/s + watts for the DFS
   energy-per-token policy.

Frequency semantics (DESIGN.md §C2): an island's normalized rate f scales
the *service rate* of its components — compute FLOP/s for accelerator
islands, link bandwidth + memory-controller service for the noc_mem island.
Energy: P(f) = P_static + P_dyn · f · V(f)^2 with V(f) = 0.7 + 0.3 f
(classic DVFS voltage scaling).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.islands import IslandConfig
from repro.core.noc import (Flow, NocConfig, NocModel,
                            collective_bytes_ring_allreduce, hops,
                            pos_index, routing_tables)
from repro.core.tiles import TilePlan
from repro.core.voltage import TechModel

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (per chip) — the assignment's numbers.
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
VMEM_BYTES = 128 * 2**20

# ---------------------------------------------------------------------------
# THE shared energy-model constants block.  Every layer that charges
# energy — grid_sweep/_eval_grid, the sharded flat-point evaluator, the
# sequential/batched tick engines, the Pallas tick kernel, the examples —
# imports these instead of re-deriving its own literals (the 0.7/0.3
# voltage coefficients and the 0.3 NoC power share used to be duplicated
# across four modules; a cross-layer drift test pins them together).
# ---------------------------------------------------------------------------
P_STATIC_W = 60.0            # per chip, modeled
P_DYN_W = 140.0              # at f=1, modeled
V_BASE = 0.7                 # linear voltage proxy: V(f) = V_BASE + V_SLOPE f
V_SLOPE = 0.3
NOC_POWER_SHARE = 0.3        # NoC+MEM power as a share of one tile's


def voltage(f: float) -> float:
    return V_BASE + V_SLOPE * f


def chip_power_coeffs(f_comp, busy, v0, v1, p_scale):
    """Chip power from explicit voltage-curve coefficients:
    ``p_scale * (P_STATIC_W + P_DYN_W * f * (v0 + v1 f)^2 * busy)``.

    Operators only, so it broadcasts over numpy arrays and jax tracers
    alike — the form the tech-axis sweep evaluates with per-point
    coefficient arrays."""
    v = v0 + v1 * f_comp
    return p_scale * (P_STATIC_W + P_DYN_W * f_comp * v * v * busy)


def chip_power(f_comp: float, busy: float, *,
               tech: Optional[TechModel] = None) -> float:
    """Modeled chip power at normalized rate f and duty cycle busy.

    ``tech=None`` (default) is the linear voltage proxy and keeps the
    historical expression verbatim — the bit-exact parity reference.
    With a :class:`~repro.core.voltage.TechModel`, power follows the
    node's physical curve ``power_scl * (P_static + P_dyn f V̂(f)^2)``.
    """
    if tech is None:
        return P_STATIC_W + P_DYN_W * f_comp * voltage(f_comp) ** 2 * busy
    return chip_power_coeffs(f_comp, busy, tech.v0, tech.v1,
                             tech.power_scl)


# ---------------------------------------------------------------------------
# Roofline terms (pod-scale)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds (per step)."""
    t_compute: float
    t_memory: float
    t_collective: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """max-term / sum-of-terms: 1.0 = perfectly overlapped/bound by one
        resource; lower = time wasted on non-dominant resources if nothing
        overlaps.  (Perfect overlap means step time = t_bound.)"""
        s = self.t_compute + self.t_memory + self.t_collective
        return self.t_bound / s if s > 0 else 0.0


def roofline_from_counts(flops: float, hbm_bytes: float,
                         collective_bytes: float, chips: int,
                         *, f_comp: float = 1.0, f_noc: float = 1.0,
                         peak_flops: float = PEAK_FLOPS,
                         hbm_bw: float = HBM_BW,
                         ici_bw: float = ICI_BW) -> RooflineTerms:
    """HLO totals -> per-step roofline terms.  ``flops``/``hbm_bytes`` are
    whole-program totals; collective_bytes is per-device wire bytes."""
    return RooflineTerms(
        t_compute=flops / (chips * peak_flops * f_comp),
        t_memory=hbm_bytes / (chips * hbm_bw * f_noc),
        t_collective=collective_bytes / (ici_bw * f_noc),
        flops=flops, hbm_bytes=hbm_bytes,
        collective_bytes=collective_bytes, chips=chips)


def model_flops(n_params: int, tokens: int, *, train: bool = True) -> float:
    """The 6·N·D (train) / 2·N·D (inference) convention."""
    return (6.0 if train else 2.0) * n_params * tokens


# ---------------------------------------------------------------------------
# Paper-claims engine: CHStone accelerator tiles on the 4x4 SoC
# ---------------------------------------------------------------------------


# Per-accelerator serialized wire-interface share w, calibrated so that
# gain(K) = 1 / ((1-w)/K + w) reproduces each accelerator's measured
# Table-I throughput gains.  K replicas parallelize compute AND the
# overlappable stream latency (each replica is an independent engine
# behind the AXI bridge); only the tile's shared NoC interface serializes.
WIRE_SHARE = {
    "adpcm": 0.0005,    # strongly compute-bound: gains ~K (1.97x / 3.86x)
    "dfsin": 0.003,     # compute-bound (1.97x / 3.76x)
    "gsm": 0.035,       # mixed (1.93x / 3.62x)
    "dfadd": 0.12,      # memory-bound (1.83x / 2.83x)
    "dfmul": 0.155,     # memory-bound (1.73x / 3.00x)
}


@dataclass(frozen=True)
class AccelWorkload:
    """One CHStone accelerator processing a data stream.

    ``ai`` (arithmetic intensity, ops/byte) separates compute-bound (adpcm,
    dfsin) from memory-bound (dfadd, dfmul) accelerators, as the paper
    observed empirically.  ``base_mbps`` anchors absolute throughput to
    Table I so reproduced numbers are comparable.
    """
    name: str
    base_mbps: float
    ai: float
    replication: int = 1

    @property
    def compute_bound(self) -> bool:
        return self.ai >= 8.0

    @property
    def wire_share(self) -> float:
        if self.name in WIRE_SHARE:
            return WIRE_SHARE[self.name]
        return 0.01 if self.compute_bound else 0.14


def _throughput_math(xp, base_mbps, wire_share, k, f_acc, f_noc, f_tg,
                     n_tg, hop_counts, *, own_demand, tg_demand, link_bw,
                     hop_latency_share, ref_hops):
    """The accelerator service-time model as pure array math.

    ``xp`` is the array namespace (numpy or jax.numpy); every data argument
    broadcasts, so the same expression serves the scalar wrapper, the numpy
    batch path, and the jitted jax path.  Kept in one place so the three
    paths can never drift.
    """
    f_acc = xp.maximum(f_acc, 1e-3)
    f_noc = xp.maximum(f_noc, 1e-3)
    w = wire_share
    # NoC saturation: proportional sharing of the f_noc-scaled capacity
    load = own_demand + tg_demand * f_tg * n_tg
    slow = xp.maximum(1.0, load / (link_bw * f_noc))
    hopf = 1.0 + hop_latency_share * hop_counts
    t = (1.0 - w) / (k * f_acc) + w * slow * hopf / f_noc
    # normalize to Table I conditions (A1, K=1, f=1, no TG)
    hopf0 = 1.0 + hop_latency_share * ref_hops
    t0 = (1.0 - w) + w * max(1.0, own_demand) * hopf0
    return base_mbps * t0 / t


@lru_cache(maxsize=32)
def _jitted_throughput_kernel(own_demand: float, tg_demand: float,
                              link_bw: float, hop_latency_share: float,
                              ref_hops: float):
    """jax.jit-compiled throughput kernel, cached per model constants
    (closed over as compile-time constants; built on first use; bounded —
    many-model chunked sweeps must not pin one executable per config).

    Note: runs at jax's default precision — enable jax_enable_x64 for
    float64 parity with the numpy path; otherwise expect ~1e-6 relative
    deviations from float32 rounding.
    """
    import jax
    import jax.numpy as jnp

    def kernel(base_mbps, wire_share, k, f_acc, f_noc, f_tg, n_tg,
               hop_counts):
        return _throughput_math(
            jnp, base_mbps, wire_share, k, f_acc, f_noc, f_tg, n_tg,
            hop_counts, own_demand=own_demand, tg_demand=tg_demand,
            link_bw=link_bw, hop_latency_share=hop_latency_share,
            ref_hops=ref_hops)

    return jax.jit(kernel)


def _memory_traffic_math(xp, f_acc, f_noc, f_tg, n_tg, n_accels, *,
                         mem_service, tg_demand_fig4):
    mem_cap = mem_service * f_noc
    tg_offer = tg_demand_fig4 * f_tg * n_tg
    acc_offer = n_accels * xp.minimum(1.0, 5.0 * f_acc) * xp.minimum(1.0, f_noc)
    return xp.minimum(mem_cap, tg_offer + acc_offer)


def _memory_traffic_math_per_accel(xp, f_acc_terms, f_noc, f_tg, n_tg, *,
                                   mem_service, tg_demand_fig4):
    """Per-accelerator-island form of the Fig.-4 model: each accelerator
    offers ``min(1, 5 f_a)`` at its *own* island rate instead of ``n_accels``
    copies of one shared rate.  The offers are summed in list order
    (sequential) — the parity contract the per-island DSE sweep relies on:
    with every ``f_a`` equal, the arithmetic is the exact op sequence the
    shared-rate sweep runs, so the two agree bit for bit.
    """
    mem_cap = mem_service * f_noc
    tg_offer = tg_demand_fig4 * f_tg * n_tg
    if len(f_acc_terms) == 0:
        return xp.minimum(mem_cap, tg_offer + xp.zeros_like(f_noc))
    acc = xp.minimum(1.0, 5.0 * f_acc_terms[0])
    for f in f_acc_terms[1:]:
        acc = acc + xp.minimum(1.0, 5.0 * f)
    acc_offer = acc * xp.minimum(1.0, f_noc)
    return xp.minimum(mem_cap, tg_offer + acc_offer)


@dataclass
class SoCPerfModel:
    """The paper's SoC: accelerator tiles + TG tiles + MEM on a 4x4 NoC,
    five frequency islands.

    Service-time model per accelerator tile:
        t(K, f) = (1 - w) / (K · f_acc)  +  w · slow · hopf / f_noc
    where ``w`` is the tile's serialized wire share (WIRE_SHARE), ``slow``
    the NoC saturation factor (proportional sharing of the f_noc-scaled
    link capacity with TG flows), and ``hopf`` a per-hop latency factor
    (placement: A1 near MEM vs A2 far, paper Fig. 2).

    Evaluation comes in two shapes: the scalar methods
    (:meth:`accel_throughput`, :meth:`memory_traffic_mpkts`) keep the
    original per-point API, and the ``*_batch`` methods evaluate stacked
    arrays of design points in one vectorized pass — the DSE hot path
    (``core/dse.py:grid_sweep`` drives millions of points through them).
    The scalar methods are thin wrappers over the batch kernel, so the two
    paths cannot diverge.
    """
    noc: NocConfig = field(default_factory=lambda: NocConfig(4, 4))
    mem_pos: Tuple[int, int] = (1, 0)
    mem_service: float = 8.0        # units/cycle at f_noc=1 (Fig. 4)
    tg_demand: float = 0.07         # per active TG core at f_tg=1 (Fig. 3)
    tg_demand_fig4: float = 0.5     # Fig. 4 uses heavier TG streams
    own_demand: float = 0.1
    hop_latency_share: float = 0.03

    # ------------------------------------------------------------- helpers
    def _ref_hops(self) -> int:
        """Hops of the Table-I reference placement (A1 = (1, 1))."""
        return hops(self.noc, (1, 1), self.mem_pos)

    def hop_counts(self, pos=None, pos_idx=None) -> np.ndarray:
        """Hop counts from position(s) to the MEM tile via the cached
        routing tables.  ``pos`` is one (r, c) tuple or an (..., 2) array;
        ``pos_idx`` flat node indices."""
        tables = routing_tables(self.noc)
        mem_idx = pos_index(self.noc, self.mem_pos)
        if pos_idx is None:
            a = np.asarray(pos)
            pos_idx = a[..., 0] * self.noc.cols + a[..., 1]
        return tables.hop_matrix[np.asarray(pos_idx), mem_idx]

    # -------------------------------------------------------- batched API
    def accel_throughput_batch(self, *, base_mbps, wire_share, k,
                               f_acc, f_noc, f_tg=1.0, n_tg=0,
                               pos=None, pos_idx=None,
                               backend: str = "numpy") -> np.ndarray:
        """Throughput (MB/s) for a stacked batch of design points.

        Every argument broadcasts against the others (numpy rules), so a
        full cross-product sweep passes each axis reshaped to its own
        dimension and gets the full grid back in one call:

        * ``base_mbps`` / ``wire_share`` — workload characterization
          (scalars for a single accelerator, arrays to sweep workloads),
        * ``k`` — replication counts,
        * ``f_acc`` / ``f_noc`` / ``f_tg`` — island rates,
        * ``n_tg`` — active traffic generators (scalar or array),
        * ``pos`` (one (r, c) or (..., 2) array) or ``pos_idx`` (flat node
          indices) — tile placements, resolved through the precomputed
          hop matrix (no per-point route walks),
        * ``backend`` — ``"numpy"`` (float64, the parity reference) or
          ``"jax"`` (jit-compiled; float32 unless jax_enable_x64).
        """
        hop_counts = self.hop_counts(pos=pos, pos_idx=pos_idx)
        consts = dict(own_demand=self.own_demand, tg_demand=self.tg_demand,
                      link_bw=self.noc.link_bw,
                      hop_latency_share=self.hop_latency_share,
                      ref_hops=self._ref_hops())
        if backend == "jax":
            kern = _jitted_throughput_kernel(
                self.own_demand, self.tg_demand, self.noc.link_bw,
                self.hop_latency_share, float(consts["ref_hops"]))
            out = kern(base_mbps, wire_share, k, f_acc, f_noc, f_tg, n_tg,
                       hop_counts)
            return np.asarray(out)
        arrs = [np.asarray(a, dtype=np.float64)
                for a in (base_mbps, wire_share, k, f_acc, f_noc, f_tg, n_tg)]
        return _throughput_math(np, *arrs, hop_counts, **consts)

    def service_time_terms_batch(self, *, wire_share, k,
                                 f_acc, f_noc, f_tg=1.0, n_tg=0,
                                 pos=None, pos_idx=None, hop_counts=None):
        """Decomposed service time of the throughput kernel (numpy only).

        Returns ``(t_comp, t_wire, t_ref)`` — the compute term
        ``(1-w)/(K f_acc)``, the serialized wire/NoC term
        ``w·slow·hopf/f_noc``, and the Table-I normalization ``t0`` — such
        that ``base_mbps * t_ref / (t_comp + t_wire)`` equals
        :meth:`accel_throughput_batch` exactly (tested).  The simulation
        engine consumes the split form: ``t_wire/(t_comp+t_wire)`` is the
        stream-boundness signal the Fig.-4 DFS policy keys on, and dynamic
        NoC contention (from live per-tick flows) scales ``t_wire`` alone,
        leaving the compute term untouched.

        ``hop_counts`` overrides the tile->MEM hop lookup with explicit
        per-stream hop counts — how tile-to-tile flow patterns reuse this
        kernel with each stream's actual route length.
        """
        if hop_counts is None:
            hop_counts = self.hop_counts(pos=pos, pos_idx=pos_idx)
        w = np.asarray(wire_share, dtype=np.float64)
        k = np.asarray(k, dtype=np.float64)
        f_acc = np.maximum(np.asarray(f_acc, dtype=np.float64), 1e-3)
        f_noc = np.maximum(np.asarray(f_noc, dtype=np.float64), 1e-3)
        f_tg = np.asarray(f_tg, dtype=np.float64)
        n_tg = np.asarray(n_tg, dtype=np.float64)
        load = self.own_demand + self.tg_demand * f_tg * n_tg
        slow = np.maximum(1.0, load / (self.noc.link_bw * f_noc))
        hopf = 1.0 + self.hop_latency_share * hop_counts
        t_comp = (1.0 - w) / (k * f_acc)
        t_wire = w * slow * hopf / f_noc
        hopf0 = 1.0 + self.hop_latency_share * self._ref_hops()
        t_ref = (1.0 - w) + w * max(1.0, self.own_demand) * hopf0
        return t_comp, t_wire, t_ref

    def memory_traffic_batch(self, *, f_acc=None, f_noc, f_tg=1.0, n_tg=0,
                             n_accels=1,
                             f_acc_per_accel=None) -> np.ndarray:
        """Batched Fig.-4 memory-traffic model (broadcasting arguments).

        Two forms: the shared-rate form takes one ``f_acc`` plus
        ``n_accels`` — the number of accelerator tiles streaming to MEM
        (the scalar API's ``len(accel_positions)``; the offer is
        position-independent).  The per-island form takes
        ``f_acc_per_accel`` — a sequence of rate arrays, one per
        accelerator island, each broadcasting over the design axes — and
        sums each accelerator's offer at its *own* island rate (the
        per-island DSE sweep's objective; bit-for-bit equal to the shared
        form when every entry carries equal rates)."""
        if f_acc_per_accel is not None:
            assert f_acc is None, "pass f_acc or f_acc_per_accel, not both"
            terms = [np.asarray(f, dtype=np.float64)
                     for f in f_acc_per_accel]
            arrs = [np.asarray(a, dtype=np.float64)
                    for a in (f_noc, f_tg, n_tg)]
            return _memory_traffic_math_per_accel(
                np, terms, *arrs, mem_service=self.mem_service,
                tg_demand_fig4=self.tg_demand_fig4)
        arrs = [np.asarray(a, dtype=np.float64)
                for a in (f_acc, f_noc, f_tg, n_tg, n_accels)]
        return _memory_traffic_math(
            np, *arrs, mem_service=self.mem_service,
            tg_demand_fig4=self.tg_demand_fig4)

    # --------------------------------------------------------- scalar API
    def accel_throughput(self, wl: AccelWorkload, pos: Tuple[int, int],
                         rates: Dict[str, float], n_tg: int) -> float:
        """Throughput (MB/s) of one accelerator tile under contention.

        Thin wrapper over :meth:`accel_throughput_batch` (same kernel)."""
        out = self.accel_throughput_batch(
            base_mbps=wl.base_mbps, wire_share=wl.wire_share,
            k=wl.replication, f_acc=rates.get("acc", 1.0),
            f_noc=rates.get("noc_mem", 1.0), f_tg=rates.get("tg", 1.0),
            n_tg=n_tg, pos=pos)
        return float(out)

    def memory_traffic_mpkts(self, rates: Dict[str, float], n_tg: int,
                             accel_positions: List[Tuple[int, int]],
                             pkt_bytes: float = 64.0) -> float:
        """Incoming memory traffic (Mpkt/s-shaped, normalized) — Fig. 4.

        TG cores offer f_tg-scaled demand; memory-bound accelerators
        saturate their stream path at low f_acc already, so traffic is
        *almost independent of f_acc* — the paper's headline observation.
        Thin wrapper over :meth:`memory_traffic_batch`."""
        out = self.memory_traffic_batch(
            f_acc=rates.get("acc", 1.0), f_noc=rates.get("noc_mem", 1.0),
            f_tg=rates.get("tg", 1.0), n_tg=n_tg,
            n_accels=len(accel_positions))
        return float(out)


def _default_tg_positions(noc: NocConfig, mem: Tuple[int, int],
                          skip: Tuple[int, int]) -> List[Tuple[int, int]]:
    out = []
    for r in range(noc.rows):
        for c in range(noc.cols):
            if (r, c) in (mem, skip, (0, 0), (0, 3), (1, 1)):
                continue
            out.append((r, c))
    return out
