"""Tiles — the unit of Vespa's design space, mapped to vespa-jax.

A Vespa SoC is a grid of tiles on a NoC; a vespa-jax "SoC" is a model whose
modules ("accelerators") are mapped onto sub-meshes of the TPU pod.  A
:class:`TileSpec` carries the paper's per-tile design-time knobs:

* ``replication``  — the MRA factor K (paper contribution C1),
* ``island``       — frequency-island assignment (C2),
* ``monitors``     — which of the four counters are enabled (C3, ≤4),
* ``placement``    — logical position on the NoC grid (paper Fig. 2: A1 near
                     MEM vs A2 far; placement changes hop counts).

A :class:`TilePlan` assigns every module family of an architecture to a tile
and is consumed by core/replication.py (sharding rules), core/islands.py
(island partition + resynchronizers), core/monitor.py (counter tree) and
core/perfmodel.py (roofline terms per tile).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig

MONITOR_KINDS = ("exec_time", "pkts_in", "pkts_out", "rtt")

# Module families a tile can host (the "accelerator" classes of the model).
TILE_KINDS = (
    "embed",        # embedding + lm_head (vocab tile)
    "attn",         # attention block-group
    "ffn",          # dense MLP block-group
    "moe",          # routed experts
    "ssm",          # mamba mixer block-group
    "shared_attn",  # zamba shared tile (one physical, many logical users)
    "noc",          # the interconnect itself (collectives fabric)
    "mem",          # HBM/optimizer state ("memory controller")
    "io",           # host data-pipeline tile
)


@dataclass(frozen=True)
class TileSpec:
    name: str
    kind: str
    island: str = "default"
    replication: int = 1                 # MRA factor K  (C1)
    placement: Tuple[int, int] = (0, 0)  # NoC grid position
    monitors: Tuple[str, ...] = ("exec_time", "pkts_in", "pkts_out")

    def __post_init__(self):
        assert self.kind in TILE_KINDS, self.kind
        assert len(self.monitors) <= 4, "paper allows up to 4 counters/tile"
        assert all(m in MONITOR_KINDS for m in self.monitors), self.monitors
        assert self.replication >= 1


@dataclass(frozen=True)
class TilePlan:
    """Tile assignment for one architecture instance."""
    arch: str
    tiles: Tuple[TileSpec, ...]

    def tile(self, name: str) -> TileSpec:
        for t in self.tiles:
            if t.name == name:
                return t
        raise KeyError(name)

    def by_kind(self, kind: str) -> List[TileSpec]:
        return [t for t in self.tiles if t.kind == kind]

    def islands(self) -> Dict[str, List[TileSpec]]:
        out: Dict[str, List[TileSpec]] = {}
        for t in self.tiles:
            out.setdefault(t.island, []).append(t)
        return out

    def with_replication(self, tile_name: str, k: int) -> "TilePlan":
        """The paper's K knob: change a tile's replication without touching
        anything else (the module definition and mesh stay fixed)."""
        tiles = tuple(
            replace(t, replication=k) if t.name == tile_name else t
            for t in self.tiles)
        return replace(self, tiles=tiles)


def default_plan(cfg: ArchConfig) -> TilePlan:
    """Baseline plan: paper-faithful island split (accelerators / NoC+MEM /
    IO) with K=1 everywhere.  Placement mirrors the paper's floorplan idea:
    compute tiles fill the grid, MEM at (1,0), IO at (0,3)."""
    tiles: List[TileSpec] = [
        TileSpec("embed", "embed", island="acc", placement=(0, 1)),
        TileSpec("noc", "noc", island="noc_mem", placement=(2, 2),
                 monitors=("pkts_in", "pkts_out")),
        TileSpec("mem", "mem", island="noc_mem", placement=(1, 0),
                 monitors=("pkts_in", "pkts_out", "rtt")),
        TileSpec("io", "io", island="cpu_io", placement=(0, 3),
                 monitors=("exec_time",)),
    ]
    if cfg.family in ("dense", "moe"):
        tiles.append(TileSpec("attn", "attn", island="acc", placement=(1, 1)))
        if cfg.family == "moe":
            tiles.append(TileSpec("moe", "moe", island="acc", placement=(3, 3)))
            if cfg.n_dense_layers:
                tiles.append(TileSpec("ffn", "ffn", island="acc",
                                      placement=(2, 3)))
        else:
            tiles.append(TileSpec("ffn", "ffn", island="acc", placement=(3, 3)))
    if cfg.family in ("ssm", "hybrid"):
        tiles.append(TileSpec("ssm", "ssm", island="acc", placement=(1, 1)))
    if cfg.family == "hybrid":
        tiles.append(TileSpec("shared_attn", "shared_attn", island="acc",
                              placement=(2, 1)))
        tiles.append(TileSpec("ffn", "ffn", island="acc", placement=(3, 3)))
    return TilePlan(arch=cfg.name, tiles=tuple(tiles))


def validate_plan(plan: TilePlan, cfg: ArchConfig) -> None:
    names = [t.name for t in plan.tiles]
    assert len(names) == len(set(names)), "duplicate tile names"
    kinds = {t.kind for t in plan.tiles}
    assert "noc" in kinds and "mem" in kinds, "plan needs noc + mem tiles"
    if cfg.family in ("dense", "moe"):
        assert "attn" in kinds
    if cfg.family in ("ssm", "hybrid"):
        assert "ssm" in kinds
    for t in plan.tiles:
        if t.kind in ("noc", "mem", "io"):
            assert t.replication == 1, f"{t.kind} tile is not replicable"
