"""NoC model: topology, XY routing, link-level contention.

The paper's SoC is a 4x4 mesh NoC; a TPU pod is a 2D (v5e: 16x16) ICI
torus.  Both are grids with per-link bandwidth and hop latency, so one model
serves the paper-claims benchmarks (4x4, CHStone tiles) and the pod-scale
perf model (16x16, layer tiles).

Contention: per-link utilization rho from summed flows; the service
slowdown uses an M/D/1-style factor 1 + rho/(2(1-rho)) capped at
``max_slowdown`` — an analytic stand-in for the RTL backpressure the paper
measures (DESIGN.md assumption #4).  This reproduces the paper's Fig. 3
shape: compute-bound tiles are flat under background traffic until the NoC
saturates; memory-bound tiles collapse as rho -> 1.

Batched evaluation (the DSE hot path): :func:`routing_tables` precomputes,
once per :class:`NocConfig`, the all-pairs hop matrix and a ragged
route->link incidence table.  Hop counts for B (src, dst) pairs become one
gather (:func:`hops_batch`); accumulating B flows onto links becomes one
``bincount`` (:func:`link_loads_batch`); the worst-link utilization along B
routes becomes one segmented reduction (:func:`route_max_utilization`).
Scalar ``xy_route``/``hops`` are memoized per ``(cfg, src, dst)`` so the
remaining scalar callers stop re-walking routes on every query.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

Pos = Tuple[int, int]
Link = Tuple[Pos, Pos]


@dataclass(frozen=True)
class NocConfig:
    rows: int = 4
    cols: int = 4
    torus: bool = False               # paper NoC: mesh; TPU ICI: torus
    link_bw: float = 1.0              # bytes/cycle per link (normalized)
    hop_latency: float = 1.0          # cycles per hop
    max_slowdown: float = 50.0


# Cache bounds: chunked many-config sweeps touch an unbounded stream of
# NocConfigs (every distinct link_bw/size/torus is a fresh key), so the
# route/hop/table caches carry explicit maxsize instead of growing for the
# life of the process.  Sizing: a pod-scale 16x16 grid has 256^2 = 65536
# (src, dst) pairs, so 1<<17 route/hop entries hold two pod-size configs
# (or ~500 SoC-size ones) before eviction; routing tables are the big rows
# (hop matrix + ragged incidence), so only a handful stay resident.
_ROUTE_CACHE_SIZE = 1 << 17
_TABLE_CACHE_SIZE = 16


@lru_cache(maxsize=_ROUTE_CACHE_SIZE)
def _xy_route_cached(cfg: NocConfig, src: Pos, dst: Pos) -> Tuple[Link, ...]:
    """Dimension-ordered (X then Y) route; shortest-wrap when torus.

    Memoized per ``(cfg, src, dst)`` — NocConfig is a frozen dataclass, so
    the triple is hashable and each route is walked at most once per
    cache residency.  The cached tuple is immutable; :func:`xy_route`
    copies it."""
    links: List[Link] = []
    r, c = src

    def step_toward(cur: int, tgt: int, size: int) -> int:
        if cur == tgt:
            return cur
        if not cfg.torus:
            return cur + (1 if tgt > cur else -1)
        fwd = (tgt - cur) % size
        bwd = (cur - tgt) % size
        return (cur + 1) % size if fwd <= bwd else (cur - 1) % size

    while c != dst[1]:
        nc = step_toward(c, dst[1], cfg.cols)
        links.append(((r, c), (r, nc)))
        c = nc
    while r != dst[0]:
        nr = step_toward(r, dst[0], cfg.rows)
        links.append(((r, c), (nr, c)))
        r = nr
    return tuple(links)


def xy_route(cfg: NocConfig, src: Pos, dst: Pos) -> List[Link]:
    """Dimension-ordered (X then Y) route; shortest-wrap when torus."""
    return list(_xy_route_cached(cfg, src, dst))


@lru_cache(maxsize=_ROUTE_CACHE_SIZE)
def hops(cfg: NocConfig, src: Pos, dst: Pos) -> int:
    return len(_xy_route_cached(cfg, src, dst))


# ---------------------------------------------------------------------------
# Precomputed routing tables: the batched fast path
# ---------------------------------------------------------------------------


def pos_index(cfg: NocConfig, pos: Pos) -> int:
    """Flat node index of a grid position (row-major)."""
    return pos[0] * cfg.cols + pos[1]


def index_pos(cfg: NocConfig, idx: int) -> Pos:
    return (idx // cfg.cols, idx % cfg.cols)


@dataclass(frozen=True, eq=False)
class RoutingTables:
    """All-pairs routing of one :class:`NocConfig`, as arrays.

    ``hop_matrix[s, d]`` is the XY hop count from node ``s`` to node ``d``
    (flat row-major indices).  The route of pair ``p = s * n_nodes + d``
    occupies ``link_ids[route_offsets[p] : route_offsets[p + 1]]`` — a
    ragged route->link incidence table that scales to pod-size grids
    (a dense (N^2, L) matrix is available via :meth:`dense_incidence` for
    small fabrics).
    """
    cfg: NocConfig
    links: Tuple[Link, ...]                 # directed links, table order
    link_index: Dict[Link, int]             # inverse of ``links``
    hop_matrix: np.ndarray                  # (N, N) int32
    link_ids: np.ndarray                    # (sum hops,) int32
    route_offsets: np.ndarray               # (N*N + 1,) int64

    @property
    def n_nodes(self) -> int:
        return self.cfg.rows * self.cfg.cols

    @property
    def n_links(self) -> int:
        return len(self.links)

    def dense_incidence(self) -> np.ndarray:
        """(N*N, L) boolean route->link incidence (small fabrics only)."""
        n2 = self.n_nodes * self.n_nodes
        inc = np.zeros((n2, self.n_links), dtype=bool)
        rows = np.repeat(np.arange(n2), np.diff(self.route_offsets))
        inc[rows, self.link_ids] = True
        return inc


@lru_cache(maxsize=_TABLE_CACHE_SIZE)
def routing_tables(cfg: NocConfig) -> RoutingTables:
    """Build (once per resident config) the hop matrix + link incidence
    tables.  Bounded: a many-config sweep evicts the least-recently-used
    tables instead of retaining one incidence table per config forever
    (tested)."""
    n = cfg.rows * cfg.cols
    link_index: Dict[Link, int] = {}
    links: List[Link] = []
    hop = np.zeros((n, n), dtype=np.int32)
    ids: List[int] = []
    offsets = np.zeros(n * n + 1, dtype=np.int64)
    p = 0
    for s in range(n):
        src = index_pos(cfg, s)
        for d in range(n):
            route = _xy_route_cached(cfg, src, index_pos(cfg, d))
            hop[s, d] = len(route)
            for link in route:
                if link not in link_index:
                    link_index[link] = len(links)
                    links.append(link)
                ids.append(link_index[link])
            p += 1
            offsets[p] = len(ids)
    return RoutingTables(cfg=cfg, links=tuple(links), link_index=link_index,
                         hop_matrix=hop,
                         link_ids=np.asarray(ids, dtype=np.int32),
                         route_offsets=offsets)


def positions_to_indices(cfg: NocConfig, positions) -> np.ndarray:
    """(..., 2) (row, col) array -> flat node indices (row-major)."""
    a = np.asarray(positions)
    return a[..., 0] * cfg.cols + a[..., 1]


def _as_indices(cfg: NocConfig, pos) -> np.ndarray:
    """Coerce to flat node indices.

    A single ``(r, c)`` tuple is converted; any other input is already
    flat indices (use :func:`positions_to_indices` for (..., 2) arrays —
    a length-2 index array is ambiguous otherwise).
    """
    if isinstance(pos, tuple) and len(pos) == 2 and all(
            isinstance(x, (int, np.integer)) for x in pos):
        return np.asarray(pos_index(cfg, pos))
    return np.asarray(pos)


def hops_batch(cfg: NocConfig, src, dst) -> np.ndarray:
    """Hop counts for B (src, dst) pairs: one gather from the hop matrix.

    ``src``/``dst`` broadcast against each other; each is either flat node
    indices (see :func:`positions_to_indices`) or a single (r, c) tuple.
    """
    t = routing_tables(cfg)
    return t.hop_matrix[_as_indices(cfg, src), _as_indices(cfg, dst)]


def _route_segments(t: RoutingTables, src, dst):
    """Gathered link ids + segment bounds for a batch of routes."""
    cfg = t.cfg
    s = np.ravel(_as_indices(cfg, src))
    d = np.ravel(_as_indices(cfg, dst))
    s, d = np.broadcast_arrays(s, d)
    pair = s * t.n_nodes + d
    starts = t.route_offsets[pair]
    counts = (t.route_offsets[pair + 1] - starts).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int32), counts
    # ragged gather: route i contributes link_ids[starts[i] : starts[i]+counts[i]]
    cum = np.concatenate(([0], np.cumsum(counts)))[:-1]
    flat = np.repeat(starts, counts) + (np.arange(total) - np.repeat(cum, counts))
    return t.link_ids[flat], counts


def stacked_incidence(cfg: NocConfig, src, dst) -> np.ndarray:
    """Dense route->link incidence for a batch of (src, dst) pairs.

    Returns a ``(..., n_links)`` float64 0/1 array where entry
    ``[..., l]`` is 1 iff the XY route of the corresponding (src, dst)
    pair traverses link ``l`` (RoutingTables link order).  ``src``/``dst``
    broadcast like :func:`hops_batch`.

    This is the *stacked/padded* export the batched co-simulation engine
    consumes: every route, whatever its hop count, is padded out to the
    full ``n_links``-wide row (zeros on unused links), so per-design
    per-tile routes stack into one rectangular ``(B, A, L)`` table and
    per-tick link loads become a single einsum instead of B ragged
    gathers.  Dense rows cost ``n_links`` floats each — fine for SoC-size
    fabrics (a 4x4 mesh has 48 directed links); pod-size grids should
    keep using the ragged ``link_ids``/``route_offsets`` tables.
    """
    t = routing_tables(cfg)
    s = _as_indices(cfg, src)
    d = _as_indices(cfg, dst)
    s, d = np.broadcast_arrays(s, d)
    shape = s.shape
    sflat = s.ravel()
    ids, counts = _route_segments(t, sflat, d.ravel())
    inc = np.zeros((sflat.shape[0], t.n_links), dtype=np.float64)
    if ids.size:
        rows = np.repeat(np.arange(counts.shape[0]), counts)
        inc[rows, ids] = 1.0
    return inc.reshape(shape + (t.n_links,))


def flow_incidence(cfg: NocConfig, src, dst) -> Tuple[np.ndarray, np.ndarray]:
    """(dense incidence, hop counts) for a batch of (src, dst) flows.

    The one-call export the simulator's flow compiler consumes: one
    broadcast of the (src, dst) pair arrays yields both the padded
    ``(..., n_links)`` route->link incidence (:func:`stacked_incidence`
    layout) and the matching ``(...,)`` hop counts gathered from the
    precomputed hop matrix — so arbitrary tile-to-tile patterns pay the
    same single table lookup the legacy tile->MEM pattern does.
    """
    t = routing_tables(cfg)
    s = _as_indices(cfg, src)
    d = _as_indices(cfg, dst)
    s, d = np.broadcast_arrays(s, d)
    return (stacked_incidence(cfg, s, d), t.hop_matrix[s, d])


def link_loads_batch(cfg: NocConfig, src, dst, demand) -> np.ndarray:
    """Per-link offered load (bytes/cycle) of B flows: one bincount.

    Equivalent to calling :meth:`NocModel.add_flow` B times, but O(total
    hops) array work instead of per-flow Python route walks.  Returns a
    dense (n_links,) vector in :class:`RoutingTables` link order.
    """
    t = routing_tables(cfg)
    ids, counts = _route_segments(t, src, dst)
    w = np.repeat(np.broadcast_to(np.asarray(demand, dtype=np.float64),
                                  counts.shape), counts)
    return np.bincount(ids, weights=w, minlength=t.n_links)


def route_max_utilization(cfg: NocConfig, link_loads: np.ndarray,
                          src, dst) -> np.ndarray:
    """Worst-link utilization rho along each of B routes (segmented max)."""
    t = routing_tables(cfg)
    ids, counts = _route_segments(t, src, dst)
    rho = np.asarray(link_loads, dtype=np.float64) / cfg.link_bw
    out = np.zeros(counts.shape, dtype=np.float64)
    nz = counts > 0
    if ids.size:
        seg_starts = np.concatenate(([0], np.cumsum(counts)))[:-1]
        out[nz] = np.maximum.reduceat(rho[ids], seg_starts[nz])
    return out


def contention_slowdown(rho, max_slowdown: float):
    """M/D/1-style service slowdown from utilization (vectorized)."""
    r = np.minimum(rho, 0.999)
    return np.minimum(1.0 + r / (2.0 * (1.0 - r)), max_slowdown)


@dataclass
class Flow:
    src: Pos
    dst: Pos
    bytes_per_cycle: float          # offered load at the flow's island rate


class NocModel:
    """Accumulates flows onto links and answers contention queries."""

    def __init__(self, cfg: NocConfig):
        self.cfg = cfg
        self.link_load: Dict[Link, float] = {}
        self.flows: List[Flow] = []

    def add_flow(self, f: Flow) -> None:
        self.flows.append(f)
        for link in _xy_route_cached(self.cfg, f.src, f.dst):
            self.link_load[link] = self.link_load.get(link, 0.0) + f.bytes_per_cycle

    def add_flows(self, flows: Iterable[Flow]) -> None:
        """Batched add: route all flows via the incidence tables at once."""
        flows = list(flows)
        if not flows:
            return
        self.flows.extend(flows)
        t = routing_tables(self.cfg)
        loads = link_loads_batch(
            self.cfg,
            positions_to_indices(self.cfg, [f.src for f in flows]),
            positions_to_indices(self.cfg, [f.dst for f in flows]),
            np.asarray([f.bytes_per_cycle for f in flows]))
        for i in np.nonzero(loads)[0]:
            link = t.links[int(i)]
            self.link_load[link] = self.link_load.get(link, 0.0) + float(loads[i])

    def _load_vector(self) -> np.ndarray:
        t = routing_tables(self.cfg)
        v = np.zeros(t.n_links)
        for link, load in self.link_load.items():
            v[t.link_index[link]] = load
        return v

    def utilization(self, link: Link) -> float:
        return self.link_load.get(link, 0.0) / self.cfg.link_bw

    def max_utilization(self) -> float:
        if not self.link_load:
            return 0.0
        return max(self.utilization(l) for l in self.link_load)

    def slowdown(self, src: Pos, dst: Pos) -> float:
        """M/D/1-style service slowdown along a route (worst link)."""
        rho = 0.0
        for link in _xy_route_cached(self.cfg, src, dst):
            rho = max(rho, min(self.utilization(link), 0.999))
        s = 1.0 + rho / (2.0 * (1.0 - rho))
        return float(min(s, self.cfg.max_slowdown))

    def slowdown_batch(self, src, dst) -> np.ndarray:
        """Slowdowns for B (src, dst) routes in one segmented reduction."""
        rho = route_max_utilization(self.cfg, self._load_vector(), src, dst)
        return contention_slowdown(rho, self.cfg.max_slowdown)

    def route_latency(self, src: Pos, dst: Pos) -> float:
        """Cycles for a packet header to traverse, incl. queueing."""
        base = hops(self.cfg, src, dst) * self.cfg.hop_latency
        return base * self.slowdown(src, dst)


def collective_bytes_ring_allreduce(size_bytes: float, n: int) -> float:
    """Per-device wire bytes of a ring all-reduce (2(n-1)/n x size)."""
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * size_bytes


def collective_bytes_allgather(size_bytes: float, n: int) -> float:
    """Per-device wire bytes to all-gather a sharded tensor of total size."""
    if n <= 1:
        return 0.0
    return (n - 1) / n * size_bytes


def collective_bytes_alltoall(size_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    return (n - 1) / n * size_bytes
