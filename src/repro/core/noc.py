"""NoC model: topology, XY routing, link-level contention.

The paper's SoC is a 4x4 mesh NoC; a TPU pod is a 2D (v5e: 16x16) ICI
torus.  Both are grids with per-link bandwidth and hop latency, so one model
serves the paper-claims benchmarks (4x4, CHStone tiles) and the pod-scale
perf model (16x16, layer tiles).

Contention: per-link utilization rho from summed flows; the service
slowdown uses an M/D/1-style factor 1 + rho/(2(1-rho)) capped at
``max_slowdown`` — an analytic stand-in for the RTL backpressure the paper
measures (DESIGN.md assumption #4).  This reproduces the paper's Fig. 3
shape: compute-bound tiles are flat under background traffic until the NoC
saturates; memory-bound tiles collapse as rho -> 1.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

Pos = Tuple[int, int]
Link = Tuple[Pos, Pos]


@dataclass(frozen=True)
class NocConfig:
    rows: int = 4
    cols: int = 4
    torus: bool = False               # paper NoC: mesh; TPU ICI: torus
    link_bw: float = 1.0              # bytes/cycle per link (normalized)
    hop_latency: float = 1.0          # cycles per hop
    max_slowdown: float = 50.0


def xy_route(cfg: NocConfig, src: Pos, dst: Pos) -> List[Link]:
    """Dimension-ordered (X then Y) route; shortest-wrap when torus."""
    links: List[Link] = []
    r, c = src

    def step_toward(cur: int, tgt: int, size: int) -> int:
        if cur == tgt:
            return cur
        if not cfg.torus:
            return cur + (1 if tgt > cur else -1)
        fwd = (tgt - cur) % size
        bwd = (cur - tgt) % size
        return (cur + 1) % size if fwd <= bwd else (cur - 1) % size

    while c != dst[1]:
        nc = step_toward(c, dst[1], cfg.cols)
        links.append(((r, c), (r, nc)))
        c = nc
    while r != dst[0]:
        nr = step_toward(r, dst[0], cfg.rows)
        links.append(((r, c), (nr, c)))
        r = nr
    return links


def hops(cfg: NocConfig, src: Pos, dst: Pos) -> int:
    return len(xy_route(cfg, src, dst))


@dataclass
class Flow:
    src: Pos
    dst: Pos
    bytes_per_cycle: float          # offered load at the flow's island rate


class NocModel:
    """Accumulates flows onto links and answers contention queries."""

    def __init__(self, cfg: NocConfig):
        self.cfg = cfg
        self.link_load: Dict[Link, float] = {}
        self.flows: List[Flow] = []

    def add_flow(self, f: Flow) -> None:
        self.flows.append(f)
        for link in xy_route(self.cfg, f.src, f.dst):
            self.link_load[link] = self.link_load.get(link, 0.0) + f.bytes_per_cycle

    def utilization(self, link: Link) -> float:
        return self.link_load.get(link, 0.0) / self.cfg.link_bw

    def max_utilization(self) -> float:
        if not self.link_load:
            return 0.0
        return max(self.utilization(l) for l in self.link_load)

    def slowdown(self, src: Pos, dst: Pos) -> float:
        """M/D/1-style service slowdown along a route (worst link)."""
        rho = 0.0
        for link in xy_route(self.cfg, src, dst):
            rho = max(rho, min(self.utilization(link), 0.999))
        s = 1.0 + rho / (2.0 * (1.0 - rho))
        return float(min(s, self.cfg.max_slowdown))

    def route_latency(self, src: Pos, dst: Pos) -> float:
        """Cycles for a packet header to traverse, incl. queueing."""
        base = hops(self.cfg, src, dst) * self.cfg.hop_latency
        return base * self.slowdown(src, dst)


def collective_bytes_ring_allreduce(size_bytes: float, n: int) -> float:
    """Per-device wire bytes of a ring all-reduce (2(n-1)/n x size)."""
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * size_bytes


def collective_bytes_allgather(size_bytes: float, n: int) -> float:
    """Per-device wire bytes to all-gather a sharded tensor of total size."""
    if n <= 1:
        return 0.0
    return (n - 1) / n * size_bytes


def collective_bytes_alltoall(size_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    return (n - 1) / n * size_bytes
