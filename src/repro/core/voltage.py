"""Technology-node voltage/frequency scaling: the physical DVFS model.

The linear proxy ``V(f) = 0.7 + 0.3 f`` in :mod:`repro.core.perfmodel`
treats voltage as a fixed affine function of frequency with no notion of
process node.  This module supplies the physically-grounded alternative:
Lumos-style ITRS/conservative scaling tables (45 -> 8 nm) giving each
node its nominal Vdd, threshold voltage Vth, and frequency/power/area
scaling factors, from which a :class:`TechModel` derives

* the node's **voltage curve** ``V(f) = Vth + f (Vdd - Vth)`` — the
  linear-over-threshold operating map: at the nominal DVFS ratio f=1 the
  island runs at Vdd, and as f drops the voltage falls toward (never
  below) the threshold;
* the node's **legal DVFS ratio range** ``[L, U]``: scaling below
  ``L = Vth / Vdd`` would push the operating point under threshold
  (lumos's ``DVFS_L_BOUND``), and ``U = 1.3`` is the conventional
  overdrive ceiling (``DVFS_U_BOUND``) — DFS commits are clamped to this
  range when a tech model is in the loop;
* the per-island **voltage ladder** coupled to an existing frequency
  :class:`~repro.core.islands.RateLadder` (one voltage step per
  frequency step, plus its legality mask).

Energy sites combine these with the wattage constants that stay in
:mod:`repro.core.perfmodel` (the single shared constants block):

    P(f, busy) = power_scl * (P_STATIC_W + P_DYN_W * f * V̂(f)^2 * busy)

with ``V̂(f) = v0 + v1 f`` the Vdd-normalized voltage curve — the same
functional form as the linear proxy, so every backend (numpy / jax scan
/ Pallas kernel) threads the physical model as three compile-time
scalars ``(p_scale, v0, v1)`` and the ``tech=None`` default keeps the
legacy expressions bit for bit.

This module is intentionally free of any :mod:`repro.core.perfmodel`
import (perfmodel imports *us*): it is pure scaling theory — ratios,
volts and bounds — with no wattage numbers baked in.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import numpy as np

# ---------------------------------------------------------------------------
# Lumos scaling tables (hoangt/lumos ``tech.py``): ITRS projections and
# the conservative variant, normalized to the 45 nm node.
# ---------------------------------------------------------------------------

#: Process nodes the tables cover, largest (oldest) first.
TECH_NODES: Tuple[int, ...] = (45, 32, 22, 16, 11, 8)

#: Scaling-table variants: ITRS projections vs conservative scaling.
TECH_VARIANTS: Tuple[str, ...] = ("itrs", "cons")

#: Nominal supply voltage at the 45 nm reference node (volts).
VDD_BASE = 1.0

#: Nominal supply-voltage scale per node (x ``VDD_BASE``).
VDD_SCALE: Dict[str, Dict[int, float]] = {
    "itrs": {45: 1.0, 32: 0.93, 22: 0.84, 16: 0.75, 11: 0.68, 8: 0.62},
    "cons": {45: 1.0, 32: 0.93, 22: 0.88, 16: 0.86, 11: 0.84, 8: 0.84},
}

#: Nominal core frequency scale per node (x the 45 nm frequency).
FREQ_SCALE: Dict[str, Dict[int, float]] = {
    "itrs": {45: 1.0, 32: 1.09, 22: 2.38, 16: 3.21, 11: 4.17, 8: 3.85},
    "cons": {45: 1.0, 32: 1.10, 22: 1.19, 16: 1.25, 11: 1.30, 8: 1.34},
}

#: Nominal dynamic-power scale per node (x the 45 nm power).
POWER_SCALE: Dict[str, Dict[int, float]] = {
    "itrs": {45: 1.0, 32: 0.66, 22: 0.54, 16: 0.38, 11: 0.25, 8: 0.12},
    "cons": {45: 1.0, 32: 0.71, 22: 0.52, 16: 0.39, 11: 0.29, 8: 0.22},
}

#: Area scale per node (ideal 0.5x per full node step; variant-free).
AREA_SCALE: Dict[int, float] = {
    45: 1.0, 32: 0.5, 22: 0.25, 16: 0.125, 11: 0.0625, 8: 0.03125,
}

#: Threshold voltage per node (volts; variant-free in lumos).
VTH: Dict[int, float] = {
    45: 0.3201, 32: 0.297, 22: 0.2673, 16: 0.2409, 11: 0.2178, 8: 0.198,
}

#: DVFS overdrive ceiling on the frequency/voltage ratio (all nodes).
DVFS_U_BOUND = 1.3


def dvfs_bounds(node: int, variant: str = "itrs") -> Tuple[float, float]:
    """``(L, U)`` legal DVFS ratio range of one node/variant.

    ``L = Vth / Vdd_nom`` — the ratio at which the supply hits the
    threshold voltage (lumos ``DVFS_L_BOUND``); ``U`` is the overdrive
    ceiling :data:`DVFS_U_BOUND`.
    """
    vdd_nom = VDD_SCALE[variant][node] * VDD_BASE
    return VTH[node] / vdd_nom, DVFS_U_BOUND


# A tech spec users may pass at API boundaries: an existing model, a bare
# node (45), or a (node, variant) pair.
TechSpec = Union[None, "TechModel", int, Tuple[int, str]]


@dataclass(frozen=True)
class TechModel:
    """One process node + scaling variant, with every derived scalar the
    energy sites and DFS clamps need precomputed.

    Hashable and frozen so it can ride inside ``lru_cache`` keys and the
    batched engines' explicit jit-cache signatures (two models are equal
    iff their ``(node, variant)`` agree — everything else is derived).
    """
    node: int = 45
    variant: str = "itrs"
    # derived scalars (filled in __post_init__; excluded from eq/hash so
    # the (node, variant) identity stays the cache key)
    vdd: float = field(init=False, compare=False)        # nominal volts
    vth: float = field(init=False, compare=False)        # threshold volts
    freq_scl: float = field(init=False, compare=False)
    power_scl: float = field(init=False, compare=False)
    area_scl: float = field(init=False, compare=False)
    l_bound: float = field(init=False, compare=False)    # legal f >= L
    u_bound: float = field(init=False, compare=False)    # legal f <= U
    v0: float = field(init=False, compare=False)         # V̂(0) = Vth/Vdd
    v1: float = field(init=False, compare=False)         # V̂ slope (1-v0)

    def __post_init__(self) -> None:
        if self.node not in TECH_NODES:
            raise ValueError(
                f"unknown tech node {self.node!r}; known: {TECH_NODES}")
        if self.variant not in TECH_VARIANTS:
            raise ValueError(
                f"unknown tech variant {self.variant!r}; "
                f"known: {TECH_VARIANTS}")
        vdd = VDD_SCALE[self.variant][self.node] * VDD_BASE
        vth = VTH[self.node]
        osa = object.__setattr__
        osa(self, "vdd", vdd)
        osa(self, "vth", vth)
        osa(self, "freq_scl", FREQ_SCALE[self.variant][self.node])
        osa(self, "power_scl", POWER_SCALE[self.variant][self.node])
        osa(self, "area_scl", AREA_SCALE[self.node])
        l, u = dvfs_bounds(self.node, self.variant)
        osa(self, "l_bound", l)
        osa(self, "u_bound", u)
        osa(self, "v0", vth / vdd)
        osa(self, "v1", 1.0 - vth / vdd)

    # -------------------------------------------------------- construction
    @classmethod
    def coerce(cls, spec: TechSpec) -> Optional["TechModel"]:
        """Normalize a user-facing tech spec: ``None`` stays ``None``
        (linear proxy), an int is a node at the default ITRS variant, a
        ``(node, variant)`` pair selects both, and an existing model
        passes through."""
        if spec is None or isinstance(spec, cls):
            return spec
        if isinstance(spec, int):
            return cls(node=spec)
        if isinstance(spec, (tuple, list)) and len(spec) == 2:
            return cls(node=int(spec[0]), variant=str(spec[1]))
        raise TypeError(
            f"tech spec must be None, a TechModel, a node int, or a "
            f"(node, variant) pair; got {spec!r}")

    @property
    def key(self) -> Tuple[int, str]:
        """The identity the caches key on."""
        return (self.node, self.variant)

    # ------------------------------------------------------ voltage curves
    def volt_ratio(self, f):
        """Vdd-normalized operating voltage ``V̂(f) = v0 + v1 f``.

        Vectorized and array-namespace agnostic (operators only): works
        on floats, numpy arrays, and jax tracers alike.
        """
        return self.v0 + self.v1 * f

    def volt_of_freq(self, f):
        """Absolute operating voltage (volts) at normalized rate ``f``:
        ``Vth + f (Vdd - Vth)`` — the linear-over-threshold map."""
        return self.volt_ratio(f) * self.vdd

    def freq_ratio(self, v_ratio):
        """Inverse of :meth:`volt_ratio`: the normalized frequency a
        Vdd-relative voltage sustains, ``(v_ratio - v0) / v1``.
        Vectorized; exact inverse (``freq_ratio(volt_ratio(f)) == f``)."""
        return (v_ratio - self.v0) / self.v1

    # -------------------------------------------------------- DVFS bounds
    def clamp_ratio(self, f):
        """Clamp requested DVFS ratio(s) into the legal ``[L, U]`` range
        (NaN — the batch controllers' "no request" marker — passes
        through untouched, matching ``np.clip`` semantics)."""
        return np.clip(f, self.l_bound, self.u_bound)

    def legal(self, f):
        """Elementwise legality of DVFS ratio(s) against ``[L, U]``."""
        f = np.asarray(f, dtype=np.float64)
        return (f >= self.l_bound) & (f <= self.u_bound)

    # ----------------------------------------------------- ladder coupling
    def ladder_voltages(self, ladder) -> np.ndarray:
        """The per-island voltage ladder coupled to a frequency
        :class:`~repro.core.islands.RateLadder`: the absolute operating
        voltage (volts) at every quantized frequency level."""
        return self.volt_of_freq(np.asarray(ladder.levels(),
                                            dtype=np.float64))

    def legal_levels(self, ladder) -> np.ndarray:
        """Boolean mask of ladder levels inside the legal DVFS range —
        the levels a clamped DFS commit can actually land on."""
        return self.legal(np.asarray(ladder.levels(), dtype=np.float64))

    # -------------------------------------------------------------- power
    @property
    def power_coeffs(self) -> Tuple[float, float, float]:
        """``(p_scale, v0, v1)`` — the three Python scalars every energy
        backend bakes in: ``P = p_scale * (P_STATIC_W + P_DYN_W * f *
        (v0 + v1 f)^2 * busy)``."""
        return (self.power_scl, self.v0, self.v1)

    def __repr__(self) -> str:  # compact: the identity + the bounds
        return (f"TechModel({self.node}nm/{self.variant}, "
                f"Vdd={self.vdd:.2f}V, Vth={self.vth:.3f}V, "
                f"DVFS=[{self.l_bound:.3f}, {self.u_bound:.1f}])")


def tech_axis_coeffs(techs) -> Dict[str, np.ndarray]:
    """Per-axis coefficient arrays for a sequence of tech models (the
    ``grid_sweep`` tech axis): aligned ``p_scale`` / ``v0`` / ``v1``
    float64 arrays ready for broadcast against the sweep grid."""
    models = [TechModel.coerce(t) for t in techs]
    return {
        "tech_ps": np.asarray([t.power_scl for t in models], np.float64),
        "tech_v0": np.asarray([t.v0 for t in models], np.float64),
        "tech_v1": np.asarray([t.v1 for t in models], np.float64),
    }
