"""Vespa core: the paper's three contributions as composable JAX modules.

C1 multi-replica tiles   -> tiles.py + replication.py
C2 DFS frequency islands -> islands.py + dfs.py
C3 run-time monitoring   -> monitor.py
supporting models        -> noc.py + perfmodel.py, DSE driver -> dse.py
"""
from repro.core.tiles import TilePlan, TileSpec, default_plan, validate_plan  # noqa: F401
from repro.core.replication import (  # noqa: F401
    make_mra_mesh, mra_rules, merged_rules, data_axes,
    replication_area_model, replication_throughput_model)
from repro.core.islands import (  # noqa: F401
    IslandConfig, IslandSpec, RateLadder, TILE_LADDER, NOC_LADDER,
    default_islands, validate_islands, resync_boundaries)
from repro.core.dfs import (  # noqa: F401
    DFSActuator, PIDRatePolicy, TileTelemetry, policy_memory_bound,
    policy_straggler, policy_energy_per_token, policy_energy_per_token_sweep)
from repro.core.monitor import (  # noqa: F401
    Counters, MonitorClient, PKT_BYTES, init_counters, charge,
    charge_boundary, manual_reset, bytes_of, pkts)
from repro.core.noc import (  # noqa: F401
    NocConfig, NocModel, Flow, RoutingTables, routing_tables, hops_batch,
    link_loads_batch, route_max_utilization, positions_to_indices)
from repro.core.perfmodel import (  # noqa: F401
    RooflineTerms, roofline_from_counts, model_flops, SoCPerfModel,
    AccelWorkload, PEAK_FLOPS, HBM_BW, ICI_BW, chip_power)
from repro.core.dse import (  # noqa: F401
    ClosedLoopScore, DesignPoint, SweepResult, closed_loop_score,
    grid_sweep, sweep_soc, pareto_front, pareto_front_bruteforce,
    pareto_front_indices, summarize, summarize_result)
from repro.core import dse  # noqa: F401
