"""Run-time monitoring infrastructure (paper contribution C3).

The paper exposes up to four memory-mapped counters per accelerator tile:
execution time, packets in, packets out, round-trip time.  vespa-jax keeps a
**counter pytree threaded through the jitted step function** — updating a
counter is an in-graph add (costs nothing extra on device), and reading it
is one device→host transfer, the analogue of an MMIO read over the paper's
USB-to-serial link.

Semantics match the paper:
* ``exec_time`` auto-resets when the tile starts and stops at completion —
  i.e. it holds the *latest* per-step busy value, not an accumulation;
* ``pkts_in`` / ``pkts_out`` / ``rtt`` accumulate until *manually* reset;
* only the (≤4) counters enabled in the TileSpec exist at all.

Packets are ``bytes / PKT_BYTES`` with PKT_BYTES = 512 (ICI payload quantum
standing in for the ESP NoC flit; DESIGN.md assumption #3).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiles import TilePlan, TileSpec, MONITOR_KINDS

PKT_BYTES = 512

Counters = Dict[str, Dict[str, jax.Array]]   # {tile: {kind: f32 scalar}}

ACCUMULATING = ("pkts_in", "pkts_out", "rtt")


def init_counters(plan: TilePlan) -> Counters:
    out: Counters = {}
    for t in plan.tiles:
        out[t.name] = {m: jnp.zeros((), jnp.float32) for m in t.monitors}
    return out


def bytes_of(x: Any) -> float:
    """Static byte count of an array or pytree (shape-only, trace-safe)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return float(total)


def pkts(nbytes) -> jax.Array:
    return jnp.asarray(nbytes, jnp.float32) / PKT_BYTES


def charge(counters: Counters, tile: str, *, exec_time=None, pkts_in=None,
           pkts_out=None, rtt=None) -> Counters:
    """In-graph counter update.  Disabled counters are silently skipped
    (the hardware without the counter instantiated simply has no register).

    exec_time REPLACES (auto-reset per start/stop); the others ACCUMULATE.
    Values may be traced scalars.
    """
    if tile not in counters:
        return counters
    row = dict(counters[tile])
    if exec_time is not None and "exec_time" in row:
        row["exec_time"] = jnp.asarray(exec_time, jnp.float32)
    for name, val in (("pkts_in", pkts_in), ("pkts_out", pkts_out),
                      ("rtt", rtt)):
        if val is not None and name in row:
            row[name] = row[name] + jnp.asarray(val, jnp.float32)
    out = dict(counters)
    out[tile] = row
    return out


def charge_boundary(counters: Counters, src: str, dst: str, payload) -> Counters:
    """Charge one tile-boundary stream crossing: bytes leave ``src`` and
    enter ``dst`` (the four AXI-Stream channels of the paper collapse to
    payload accounting; direction gives rd vs wr)."""
    n = pkts(bytes_of(payload))
    counters = charge(counters, src, pkts_out=n)
    counters = charge(counters, dst, pkts_in=n)
    return counters


def manual_reset(counters: Counters, tiles: Optional[Iterable[str]] = None,
                 kinds: Iterable[str] = ACCUMULATING) -> Counters:
    """Host-initiated reset of the accumulating counters (the paper's
    manually-reset semantics).  exec_time is excluded by default."""
    out = {}
    for t, row in counters.items():
        if tiles is not None and t not in tiles:
            out[t] = row
            continue
        out[t] = {k: (jnp.zeros((), jnp.float32) if k in kinds else v)
                  for k, v in row.items()}
    return out


@dataclass
class MonitorSample:
    step: int
    wall_time: float
    counters: Dict[str, Dict[str, float]]


class MonitorClient:
    """Host-side monitor — the USB-to-serial path of the paper.

    ``read()`` pulls the device counter tree once (one transfer) and stamps
    it with wall-clock; ``rates()`` differentiates consecutive samples into
    pkt/s — what the paper plots in Fig. 4.

    The sample history is bounded (``max_samples``, a deque) so long soaks
    never grow it without limit — the same fix ``ActuatorState.history``
    got; only a recent window is ever differenced or printed anyway.
    """

    def __init__(self, max_samples: int = 4096):
        self.samples: Deque[MonitorSample] = deque(maxlen=int(max_samples))
        # memoized table() column layout: recomputed only when the set of
        # tiles/kinds changes, not sorted afresh on every render
        self._layout_key: Optional[Tuple[Tuple[str, ...], ...]] = None
        self._layout: List[Tuple[str, Tuple[str, ...]]] = []

    def read(self, counters: Counters, step: int) -> MonitorSample:
        host = jax.device_get(counters)
        flat = {t: {k: float(v) for k, v in row.items()}
                for t, row in host.items()}
        s = MonitorSample(step=step, wall_time=time.monotonic(), counters=flat)
        self.samples.append(s)
        return s

    def rates(self, tile: str, kind: str = "pkts_in") -> List[Tuple[int, float]]:
        samples = list(self.samples)
        out = []
        for a, b in zip(samples, samples[1:]):
            dt = b.wall_time - a.wall_time
            if dt <= 0:
                continue
            da = b.counters[tile].get(kind, 0.0) - a.counters[tile].get(kind, 0.0)
            out.append((b.step, da / dt))
        return out

    def _columns(self, counters: Dict[str, Dict[str, float]]
                 ) -> List[Tuple[str, Tuple[str, ...]]]:
        key = tuple((t, tuple(row)) for t, row in counters.items())
        if key != self._layout_key:
            self._layout_key = key
            self._layout = [(t, tuple(sorted(counters[t])))
                            for t in sorted(counters)]
        return self._layout

    def table(self) -> str:
        if not self.samples:
            return "(no samples)"
        last = self.samples[-1]
        lines = [f"step {last.step}  t={last.wall_time:.3f}"]
        for t, kinds in self._columns(last.counters):
            row = last.counters[t]
            cols = "  ".join(f"{k}={row[k]:.3g}" for k in kinds)
            lines.append(f"  {t:12s} {cols}")
        return "\n".join(lines)
