"""Multi-replica accelerator tiles (paper contribution C1) on a TPU mesh.

The paper instantiates K replicas of an accelerator behind one NoC node,
with an AXI bridge multiplexing the tile's stream interfaces across
replicas.  Key invariants preserved here:

* the NoC (global device mesh topology) does not change,
* the accelerator (module definition) does not change,
* K is a per-tile design-time parameter,
* throughput scales ~K for stream-bound tiles at ~K area (weight bytes).

On a TPU pod the tile's fabric is the ``model`` mesh axis.  MRA-K factors it
into ``(replica=K, shard=model/K)``:

* the module's weights are sharded over ``shard`` and *replicated* over
  ``replica``  — per-device weight bytes x K (the paper's area cost),
* the tile's input token stream is *split* over ``replica`` (the AXI
  bridge = one all-to-all resharding collective at the tile boundary),
* each replica's collectives span model/K chips — (K-1)/K fewer bytes on
  the wire and 1/K the hop latency: the throughput gain for
  communication-bound tiles (measured in benchmarks/bench_replication.py).

Because each design point is a separate compiled program (the paper builds
a separate bitstream per K), a K-factored run uses ``make_mra_mesh`` — the
same physical device set, renamed sub-axes.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.tiles import TilePlan, TileSpec
from repro.models.params import Axis, BASE_RULES, rules_with

# Logical weight axes owned by each tile kind; these are the axes whose
# mesh assignment the MRA bridge rewrites when K > 1.
TILE_LOGICAL_AXES: Dict[str, Tuple[str, ...]] = {
    "embed": ("vocab",),
    "attn": ("qkv", "kv", "heads"),
    "ffn": ("ff",),
    "moe": ("expert_ff", "experts"),
    "ssm": ("d_inner", "ssm_heads", "conv_ch"),
    "shared_attn": ("qkv", "kv", "heads", "ff"),
}


def make_mra_mesh(k: int, *, multi_pod: bool = False,
                  model: int = 16, data: int = 16) -> Mesh:
    """The production mesh with the model axis K-factored.

    Physical devices and topology are identical to
    ``launch.mesh.make_production_mesh`` — only the logical axis naming
    changes, mirroring how the paper's MRA changes tile internals but not
    the NoC.  ``k`` must divide ``model``.
    """
    assert model % k == 0, (model, k)
    if multi_pod:
        return jax.make_mesh((2, data, k, model // k),
                             ("pod", "data", "replica", "shard"))
    return jax.make_mesh((data, k, model // k),
                         ("data", "replica", "shard"))


def mra_rules(plan: TilePlan, mesh: Mesh) -> Dict[str, Dict[str, Axis]]:
    """Per-tile logical->mesh rules implementing each tile's K.

    Returns {tile_name: rules_dict}.  On the baseline mesh (axis "model",
    K=1 everywhere) this reduces to BASE_RULES for every tile.  On an MRA
    mesh (axes replica/shard) a tile with replication K shards its weight
    axes over "shard" only (replicated over "replica"); a K=1 tile shards
    over both (pure TP).
    """
    names = set(mesh.axis_names)
    has_mra = "replica" in names and "shard" in names
    out: Dict[str, Dict[str, Axis]] = {}
    for t in plan.tiles:
        axes = TILE_LOGICAL_AXES.get(t.kind, ())
        if not has_mra:
            out[t.name] = dict(BASE_RULES)
            continue
        replica_k = mesh.shape["replica"]
        full_model: Axis = ("replica", "shard")
        overrides: Dict[str, Axis] = {}
        for logical, base in BASE_RULES.items():
            if base == "model":
                overrides[logical] = full_model
        for ax in axes:
            if BASE_RULES.get(ax) == "model":
                # t.replication > 1: weights replicated over "replica"
                overrides[ax] = "shard" if t.replication > 1 else full_model
        out[t.name] = rules_with(overrides)
    return out


def merged_rules(plan: TilePlan, mesh: Mesh) -> Dict[str, Axis]:
    """Single rule dict for the whole model (tile rules merged).

    Each logical axis is owned by exactly one tile kind, so the merge is
    conflict-free; shared axes (embed/norm/etc.) stay at their base value.
    """
    per_tile = mra_rules(plan, mesh)
    merged: Dict[str, Axis] = {}
    for t in plan.tiles:
        for k, v in per_tile[t.name].items():
            owner_axes = TILE_LOGICAL_AXES.get(t.kind, ())
            if k in owner_axes or k not in merged:
                merged[k] = v
    return merged


def data_axes(mesh: Mesh, plan: Optional[TilePlan] = None) -> Tuple[str, ...]:
    """Axes carrying the batch dimension.  Replica sub-axes of MRA tiles
    carry batch too (the AXI bridge splits the stream K ways)."""
    names = mesh.axis_names
    out = tuple(a for a in ("pod", "data") if a in names)
    if "replica" in names and plan is not None and any(
            t.replication > 1 for t in plan.tiles):
        out = out + ("replica",)
    return out


def replication_area_model(weight_bytes: int, act_bytes: int, k: int,
                           model: int = 16) -> Dict[str, float]:
    """Analytic per-device 'area' for an MRA tile (Table-I analogue).

    Weights: sharded over model/K devices, replicated K ways ->
    per-device weight bytes x K.  Activations/KV: split over replicas ->
    per-device unchanged.  Mirrors the paper: DSP (weights/compute) scale
    ~K, LUT/FF/BRAM (shared stream logic) scale sub-K.
    """
    return {
        "weight_bytes_per_dev": weight_bytes * k / model,
        "act_bytes_per_dev": act_bytes / model,
        "total_bytes_per_dev": (weight_bytes * k + act_bytes) / model,
    }


def replication_throughput_model(k: int, *, stream_fraction: float = 0.96
                                 ) -> float:
    """Analytic throughput gain of MRA-K for a stream-bound tile.

    Amdahl form: a fraction ``stream_fraction`` of the tile's service time
    is the serialized stream interface (collective latency / DMA round
    trips), which K replicas overlap K-ways; the rest is per-replica
    compute, unchanged.  gain(K) = 1 / ((1-c) + c/K).

    Calibration: the paper's Table I averages are 1.92x @ K=2 and
    3.58x @ K=4.  Solving gain(2)=1.92 gives c = 0.958; that same c
    predicts gain(4) = 3.55 — within 1% of the measured 3.58x, i.e. the
    paper's accelerators are ~96% stream-bound, which matches its own
    observation that dfadd/dfmul are memory-bound.
    """
    c = stream_fraction
    return 1.0 / ((1.0 - c) + c / k)
