"""DFS actuators + the dual-buffer hitless reconfiguration protocol (C2).

The paper's actuator uses two MMCMs and an FSM: the master holds the output
clock while the slave reconfigures, then roles swap — the platform never
sees a dead clock.  The vespa-jax actuator keeps two *island-config
buffers*: the live one drives the (compiled) step function while the shadow
one is rewritten; ``commit()`` atomically swaps them between steps.  Because
compiled executables are cached per config version, swapping back to a
previously-used config is instant — exactly the MMCM role swap.

Controller policies consume the run-time monitor (C3) and the perf model to
pick per-island rates:

* ``policy_memory_bound`` — the paper's Fig. 4 insight: islands whose tiles
  are memory/stream-bound can drop their clock with negligible throughput
  loss, saving energy.
* ``policy_straggler``   — islands detected slow (exec-time counter above
  the fleet median) get work rebalanced away / their admission lowered:
  DFS as straggler mitigation at pod scale.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.islands import IslandConfig, IslandSpec
from repro.core.tiles import TilePlan
from repro.core.voltage import TechModel, TechSpec


DEFAULT_HISTORY_MAXLEN = 256


@dataclass
class ActuatorState:
    live: IslandConfig
    shadow: Optional[IslandConfig] = None
    swaps: int = 0
    # bounded: long-running controllers commit thousands of swaps; only a
    # recent window is ever inspected, so old entries are evicted FIFO
    history: Deque[Tuple[int, Dict[str, float]]] = field(
        default_factory=lambda: deque(maxlen=DEFAULT_HISTORY_MAXLEN))


class DFSActuator:
    """Dual-buffer, glitch-free island-rate actuator.

    >>> act = DFSActuator(islands)
    >>> act.reconfigure({"noc_mem": 0.5})   # writes the SHADOW buffer
    >>> act.commit()                        # atomic swap between steps
    ``live()`` never observes a half-written config: reconfigure() builds a
    complete new IslandConfig aside, and commit() swaps a single reference
    under a lock (the FSM of the paper, in one CAS).
    """

    def __init__(self, initial: IslandConfig,
                 history_maxlen: int = DEFAULT_HISTORY_MAXLEN):
        self._lock = threading.Lock()
        self._st = ActuatorState(
            live=initial, history=deque(maxlen=history_maxlen))

    @property
    def history_maxlen(self) -> Optional[int]:
        return self._st.history.maxlen

    def live(self) -> IslandConfig:
        with self._lock:
            return self._st.live

    def reconfigure(self, rates: Dict[str, float]) -> IslandConfig:
        """Prepare the shadow buffer; the live config keeps driving."""
        with self._lock:
            base = self._st.live
            self._st.shadow = base.with_rates(rates)
            return self._st.shadow

    def commit(self) -> IslandConfig:
        """Swap shadow -> live (the master/slave MMCM role swap)."""
        with self._lock:
            if self._st.shadow is None:
                return self._st.live
            prev = self._st.live
            self._st.live, self._st.shadow = self._st.shadow, None
            self._st.swaps += 1
            self._st.history.append(
                (self._st.live.version,
                 {i.name: i.rate for i in self._st.live.islands}))
            return self._st.live

    def abort(self) -> None:
        """Drop a prepared shadow config without ever exposing it."""
        with self._lock:
            self._st.shadow = None

    @property
    def swaps(self) -> int:
        with self._lock:
            return self._st.swaps

    def history(self) -> List[Tuple[int, Dict[str, float]]]:
        with self._lock:
            return list(self._st.history)


# ---------------------------------------------------------------------------
# Controller policies
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TileTelemetry:
    """Per-tile digest read from the C3 monitor."""
    exec_time: float          # busy seconds (or cycles) in window
    pkts_in: float
    pkts_out: float
    rtt: float
    boundness: float          # T_mem_or_stream / T_total in [0,1]


def policy_memory_bound(islands: IslandConfig,
                        telemetry: Dict[str, TileTelemetry],
                        *, threshold: float = 0.7,
                        low_rate: float = 0.2) -> Dict[str, float]:
    """Fig.-4 policy: drop the clock of islands whose tiles are
    memory/stream-bound past ``threshold`` — their throughput is set by the
    NoC+MEM island, so f_acc barely matters; energy ~ f V(f)^2 drops.
    Never touches the noc_mem island (that's the actual bottleneck)."""
    out: Dict[str, float] = {}
    for isl in islands.islands:
        if isl.fixed or isl.name == "noc_mem":
            continue
        ts = [telemetry[t] for t in isl.tiles if t in telemetry]
        if not ts:
            continue
        b = float(np.mean([t.boundness for t in ts]))
        out[isl.name] = low_rate if b >= threshold else 1.0
    return out


def policy_straggler(islands: IslandConfig,
                     telemetry: Dict[str, TileTelemetry],
                     *, slack: float = 1.3) -> Dict[str, float]:
    """Straggler mitigation: islands whose exec-time exceeds ``slack`` x the
    median run at full rate while everyone else is derated to match — the
    fleet converges to the straggler's pace at minimum energy instead of
    spinning.  (At pod scale the same signal triggers work rebalancing in
    runtime/fault.py; rate-derating is the in-step response.)"""
    med = float(np.median([t.exec_time for t in telemetry.values()])) or 1.0
    out: Dict[str, float] = {}
    for isl in islands.islands:
        if isl.fixed:
            continue
        ts = [telemetry[t] for t in isl.tiles if t in telemetry]
        if not ts:
            continue
        worst = max(t.exec_time for t in ts)
        if worst > slack * med:
            out[isl.name] = 1.0                   # straggler: full speed
        else:
            # derate to just-keep-up: rate ~ own_time / straggler_time
            out[isl.name] = max(0.2, min(1.0, worst / (slack * med)))
    return out


class PIDRatePolicy:
    """PID-style per-island utilization tracking DFS policy.

    Interprets each tile's ``exec_time`` counter as its busy fraction over
    the sample window (what the simulation engine's C3 monitor reports)
    and servos every non-fixed island's rate so the island-mean busy
    fraction tracks ``target``: an underutilized island has headroom, so
    its clock drops (energy ~ f·V(f)^2 falls); a saturated island
    (busy -> 1, queues forming) gets its clock raised back before latency
    escapes.  Unlike :func:`policy_memory_bound` (a model-driven static
    classification) this is a purely measurement-driven feedback loop, so
    it adapts to diurnal/bursty load the model never saw.

    Stateful (per-island integral + previous error) — construct one
    instance per controlled platform.  The returned rates are continuous;
    the actuator's ladder quantization supplies the hysteresis that keeps
    small errors from dithering the clock.
    """

    def __init__(self, *, target: float = 0.7, kp: float = 0.8,
                 ki: float = 0.25, kd: float = 0.0, min_rate: float = 0.2,
                 integral_clamp: float = 2.0,
                 skip: Tuple[str, ...] = ("noc_mem",)):
        assert 0.0 < target <= 1.0
        self.target = target
        self.kp, self.ki, self.kd = kp, ki, kd
        self.min_rate = min_rate
        self.integral_clamp = integral_clamp
        self.skip = tuple(skip)
        self._integral: Dict[str, float] = {}
        self._prev_err: Dict[str, float] = {}

    def reset(self) -> None:
        self._integral.clear()
        self._prev_err.clear()

    def __call__(self, islands: IslandConfig,
                 telemetry: Dict[str, TileTelemetry]) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for isl in islands.islands:
            if isl.fixed or isl.name in self.skip:
                continue
            ts = [telemetry[t] for t in isl.tiles if t in telemetry]
            if not ts:
                continue
            util = float(np.mean([t.exec_time for t in ts]))
            err = util - self.target            # positive => overloaded
            i_term = float(np.clip(self._integral.get(isl.name, 0.0) + err,
                                   -self.integral_clamp, self.integral_clamp))
            d_term = err - self._prev_err.get(isl.name, err)
            self._integral[isl.name] = i_term
            self._prev_err[isl.name] = err
            new = isl.rate + self.kp * err + self.ki * i_term + self.kd * d_term
            out[isl.name] = float(np.clip(new, self.min_rate, 1.0))
        return out


# ---------------------------------------------------------------------------
# Vectorized (multi-design) policies — the batched co-sim counterparts
# ---------------------------------------------------------------------------
#
# The scalar policies above consume {tile: TileTelemetry} dicts for ONE
# platform; the batched simulation engine (sim/batch.py) runs B design
# points at once, so its controller harness hands policies a *sample*
# object exposing per-tile (B, A) counter windows plus island aggregation
# helpers (sim/control.py:BatchSample).  A batch policy returns a (B, I)
# array of requested island rates, with NaN meaning "no request for this
# island" — the array analogue of a scalar policy omitting a dict key.
# The math is element-for-element the scalar policies' math, so a B=1
# batch run reproduces the scalar controller bit-for-bit (tested).


class BatchMemoryBoundPolicy:
    """Vectorized :func:`policy_memory_bound`: islands whose mean tile
    stream-boundness exceeds ``threshold`` drop to ``low_rate``, everyone
    else returns to full rate; fixed islands, ``noc_mem`` and islands with
    no sampled tiles are never requested (NaN).  Stateless."""

    def __init__(self, *, threshold: float = 0.7, low_rate: float = 0.2):
        self.threshold = threshold
        self.low_rate = low_rate

    def __call__(self, rates: np.ndarray, sample) -> np.ndarray:
        b = sample.island_mean(sample.boundness)            # (B, I)
        out = np.where(b >= self.threshold, self.low_rate, 1.0)
        skip = (sample.fixed | (sample.counts == 0)
                | (np.asarray(sample.island_names) == "noc_mem"))
        out[:, skip] = np.nan
        return out


class BatchPIDRatePolicy:
    """Vectorized :class:`PIDRatePolicy`: per-(design, island) integral and
    previous-error state as (B, I) arrays, elementwise the scalar PID's
    update.  Stateful — construct one instance per controlled batch."""

    def __init__(self, *, target: float = 0.7, kp: float = 0.8,
                 ki: float = 0.25, kd: float = 0.0, min_rate: float = 0.2,
                 integral_clamp: float = 2.0,
                 skip: Tuple[str, ...] = ("noc_mem",)):
        assert 0.0 < target <= 1.0
        self.target = target
        self.kp, self.ki, self.kd = kp, ki, kd
        self.min_rate = min_rate
        self.integral_clamp = integral_clamp
        self.skip = tuple(skip)
        self._integral: Optional[np.ndarray] = None          # (B, I)
        self._prev_err: Optional[np.ndarray] = None          # (B, I)

    def reset(self) -> None:
        self._integral = None
        self._prev_err = None

    def __call__(self, rates: np.ndarray, sample) -> np.ndarray:
        rates = np.asarray(rates, dtype=np.float64)
        util = sample.island_mean(sample.busy)               # (B, I)
        skip = (sample.fixed | (sample.counts == 0)
                | np.isin(np.asarray(sample.island_names), self.skip))
        err = np.where(skip, 0.0, util - self.target)
        if self._integral is None:
            self._integral = np.zeros_like(err)
        if self._prev_err is None:
            d_term = np.zeros_like(err)        # scalar: first sample d=0
        else:
            d_term = err - self._prev_err
        i_term = np.clip(self._integral + err,
                         -self.integral_clamp, self.integral_clamp)
        self._integral = i_term
        self._prev_err = err
        new = rates + self.kp * err + self.ki * i_term + self.kd * d_term
        out = np.clip(new, self.min_rate, 1.0)
        out[:, skip] = np.nan
        return out


# Custom batch policies on the FAST backends: any object implementing the
# ``jax_step`` protocol below is lowered straight into the ``lax.scan``
# carry / Pallas kernel scratch by ``BatchSimEngine._jax_control`` — the
# fast path is no longer limited to the membound/PID pair.
#
#   jax_state(B, I) -> tuple of B-leading 2-D state arrays (the carry)
#   jax_step(rates, obs, state) -> (req, new_state)
#       rates: (B, I) live island rates;
#       obs: {"util","boundness","queue_ticks"} island-aggregated (B, I);
#       req: (B, I) with NaN = "no request" (the BatchPolicy contract);
#       state advance is committed only on control ticks by the caller.
#   jax_sync(state)       optional: write evolved state back post-run
#   jax_cache_key()       optional: hashable tuning digest (jit cache key)
#   skip_islands(topo)    optional: (I,) bool mask of never-touched islands
#
# jax_step runs inside jit/pallas: jnp ops only, no captured jnp array
# constants (scalars and the passed-in arrays are fine).


class BatchEWMAUtilizationPolicy:
    """Utilization-tracking proportional policy with EWMA smoothing —
    the reference implementation of the ``jax_step`` protocol.

    Each control tick the island's smoothed utilization ``ewma`` pulls
    the rate toward ``rates * ewma / target`` (busy islands speed up,
    idle islands slow down), clipped to ``[min_rate, 1]``.  State is the
    (B, I) EWMA plus a (B, 1) "seeded" flag (the first sample primes the
    EWMA instead of decaying from zero).  The numpy ``__call__`` and the
    ``jax_step`` lowering share the same arithmetic, so the scan/Pallas
    backends reproduce the numpy engine within float32 rounding
    (differential-tested)."""

    def __init__(self, *, alpha: float = 0.3, target: float = 0.7,
                 min_rate: float = 0.2):
        assert 0.0 < alpha <= 1.0 and 0.0 < target <= 1.0
        self.alpha = alpha
        self.target = target
        self.min_rate = min_rate
        self._ewma: Optional[np.ndarray] = None              # (B, I)

    def reset(self) -> None:
        self._ewma = None

    def _skip(self, fixed, counts, names) -> np.ndarray:
        return (np.asarray(fixed) | (np.asarray(counts) == 0)
                | (np.asarray(names) == "noc_mem"))

    # ---- numpy path (BatchControllerHarness)
    def __call__(self, rates: np.ndarray, sample) -> np.ndarray:
        rates = np.asarray(rates, dtype=np.float64)
        skip = self._skip(sample.fixed, sample.counts,
                          sample.island_names)
        util = np.where(skip, 0.0,
                        np.nan_to_num(sample.island_mean(sample.busy)))
        if self._ewma is None:
            ewma = util
        else:
            ewma = self.alpha * util + (1.0 - self.alpha) * self._ewma
        self._ewma = ewma
        out = np.clip(rates * (ewma / self.target), self.min_rate, 1.0)
        out[:, skip] = np.nan
        return out

    # ---- jax path (scan carry / pallas scratch)
    def skip_islands(self, topo) -> np.ndarray:
        return self._skip(topo.fixed, topo.counts, topo.names)

    def jax_state(self, B: int, I: int):
        if self._ewma is not None:
            return (np.asarray(self._ewma, dtype=np.float64),
                    np.ones((B, 1), dtype=bool))
        return (np.zeros((B, I)), np.zeros((B, 1), dtype=bool))

    def jax_step(self, rates, obs, state):
        import jax.numpy as jnp
        ewma_prev, has = state
        util = obs["util"]
        ewma = jnp.where(has,
                         self.alpha * util
                         + (1.0 - self.alpha) * ewma_prev,
                         util)
        req = jnp.clip(rates * (ewma / self.target), self.min_rate, 1.0)
        return req, (ewma, has | jnp.ones_like(has))

    def jax_sync(self, state) -> None:
        ewma, has = state
        if np.any(has):
            self._ewma = np.asarray(ewma, dtype=np.float64)

    def jax_cache_key(self):
        return (type(self).__qualname__, self.alpha, self.target,
                self.min_rate)


def policy_energy_per_token_sweep(
        islands: IslandConfig,
        perf_eval_batch: Callable[[Dict[str, np.ndarray]],
                                  Tuple[np.ndarray, np.ndarray]],
        *, max_loss: float = 0.02,
        tech: TechSpec = None) -> Dict[str, float]:
    """Exhaustive batched rate search minimizing energy/token.

    The batched counterpart of :func:`policy_energy_per_token`: instead of
    greedy coordinate descent with one scalar ``perf_eval`` call per probe,
    it materializes the full cross-product of every non-fixed island's rate
    ladder as stacked arrays and evaluates all configurations in ONE
    ``perf_eval_batch`` call — ``perf_eval_batch({island: rates_array})
    -> (tokens_per_s_array, watts_array)`` (built on
    ``SoCPerfModel.accel_throughput_batch`` in practice).  Ladders are
    small (9–19 levels), so the exhaustive grid is ~1e4–1e6 points, well
    inside the batched engine's budget, and — unlike coordinate descent —
    it cannot get stuck in a local minimum.

    Returns the rate assignment with the lowest watts/token among points
    whose throughput is within ``max_loss`` of the all-max-rates config.

    ``tech``: optional physical DVFS model (see
    :mod:`repro.core.voltage`); when set, the search grid is restricted
    to each ladder's levels inside the node's legal ``[L, U]`` ratio
    range, so the policy can only propose commits the harness clamp
    would accept.
    """
    tech = TechModel.coerce(tech)
    free = [isl for isl in islands.islands if not isl.fixed]
    if not free:
        return {}
    ladders = [np.asarray(isl.ladder.levels(), dtype=np.float64)
               for isl in free]
    if tech is not None:
        ladders = [lv[tech.legal(lv)] if tech.legal(lv).any() else lv
                   for lv in ladders]
    grids = np.meshgrid(*ladders, indexing="ij")
    flat = {isl.name: g.ravel() for isl, g in zip(free, grids)}
    tps, watts = perf_eval_batch(flat)
    tps = np.asarray(tps, dtype=np.float64)
    watts = np.asarray(watts, dtype=np.float64)
    # baseline = every island at its max ladder level (flat index computed
    # explicitly: a ladder whose step doesn't divide its range never
    # contains f/f_max == 1.0 as its last level)
    base_idx = np.ravel_multi_index(
        tuple(int(np.argmax(lv)) for lv in ladders),
        tuple(lv.shape[0] for lv in ladders))
    base_tps = tps[base_idx]
    feasible = tps >= (1.0 - max_loss) * base_tps
    ept = np.where(feasible, watts / np.maximum(tps, 1e-9), np.inf)
    best = int(np.argmin(ept))
    return {isl.name: float(flat[isl.name][best]) for isl in free}


def policy_energy_per_token(islands: IslandConfig,
                            telemetry: Dict[str, TileTelemetry],
                            perf_eval: Callable[[Dict[str, float]], Tuple[float, float]],
                            *, steps: int = 25,
                            tech: TechSpec = None) -> Dict[str, float]:
    """Greedy coordinate-descent over the discrete rate ladders minimizing
    energy/token subject to <2% throughput loss vs all-max rates.
    ``perf_eval(rates) -> (tokens_per_s, watts)`` comes from core/perfmodel.
    ``tech``: optional physical DVFS model — probe levels outside the
    node's legal ``[L, U]`` ratio range are skipped (the harness clamp
    would reject them anyway).
    """
    tech = TechModel.coerce(tech)
    rates = {i.name: i.rate for i in islands.islands if not i.fixed}
    base_tps, _ = perf_eval({**rates, **{k: 1.0 for k in rates}})
    best = dict(rates)
    best_tps, best_w = perf_eval(best)
    for _ in range(steps):
        improved = False
        for isl in islands.islands:
            if isl.fixed:
                continue
            for lv in isl.ladder.levels():
                if tech is not None and not tech.legal(lv):
                    continue
                cand = dict(best)
                cand[isl.name] = lv
                tps, w = perf_eval(cand)
                if tps >= 0.98 * base_tps and (w / max(tps, 1e-9)) < (
                        best_w / max(best_tps, 1e-9)) * 0.999:
                    best, best_tps, best_w = cand, tps, w
                    improved = True
        if not improved:
            break
    return best
